"""Per-layer, per-stage arithmetic-intensity (Op/B) analysis — paper §III.

The paper's C1 mechanism routes every layer of every continuous-batching stage
to the processor whose roofline knee matches the layer's Op/B. This module is
the analysis that drives it: given an architecture and a *stage composition*
(which sequences are prefilling, which are decoding, and their lengths), it
computes FLOPs, HBM bytes, and Op/B for every layer component.

All byte counts assume 2-byte (bf16/fp16) weights and activations and count
*off-chip* traffic of the operands (weights + streamed activations), matching
the paper's roofline methodology (Fig. 4(b)).

Key facts this reproduces (paper §III-A):
  * decode attention under GQA: Op/B ≈ deg_grp (4–8 for deg_grp = 4–8);
  * MoE decode: Op/B ≈ 2 · (tokens per selected expert) ≥ 1, fluctuating with
    batch size and with prefill arrivals (mixed stages);
  * FC/QKV/proj GEMMs: Op/B ≈ tokens in the stage (huge for prefill).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import (ATTN, ATTN_BIDIR, ATTN_CROSS, ATTN_LOCAL,
                                DENSE, MAMBA, MOE, NONE, LayerKind, ModelConfig)

BYTES = 2  # bf16 / fp16


# ---------------------------------------------------------------------------
# Stage composition (continuous batching, paper §II-C)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class StageMix:
    """One continuous-batching stage.

    ``decode_ctx``  — context length (KV entries attended) per decode sequence.
    ``prefill_len`` — prompt length per whole-prompt prefill sequence.
    ``chunk_spans`` — (start, end) per chunked-prefill sequence: this stage
                      processes prompt positions [start, end), attending over
                      the already-written [0, start) KV prefix plus the
                      in-flight chunk (ROADMAP "DESIGN: chunked prefill").
    ``spec_spans``  — (start, end) per speculative-decode verify sequence
                      (PR 9): a decode row carrying 1 + k tokens (last
                      sampled token + k drafts). Attention-wise identical to
                      a chunk span — queries [start, end) over the written
                      prefix — but every position's logits are sampled, so
                      the LM head produces (end - start) outputs per row.
                      Multi-token rows are the Op/B lever: attn goes from
                      1 query to k+1 queries per KV stream, and the FC/MoE
                      GEMMs amortize weights over k+1× the tokens.
    Empty prefill_len, chunk_spans and spec_spans => decoding-only stage.
    """
    decode_ctx: Tuple[int, ...] = ()
    prefill_len: Tuple[int, ...] = ()
    chunk_spans: Tuple[Tuple[int, int], ...] = ()
    spec_spans: Tuple[Tuple[int, int], ...] = ()

    @property
    def is_mixed(self) -> bool:
        return (len(self.prefill_len) > 0 or len(self.chunk_spans) > 0
                or len(self.spec_spans) > 0)

    @property
    def num_tokens(self) -> int:
        """Tokens passing through the FC/MoE layers this stage."""
        return (len(self.decode_ctx) + sum(self.prefill_len)
                + sum(e - s for s, e in self.chunk_spans)
                + sum(e - s for s, e in self.spec_spans))

    @property
    def batch_size(self) -> int:
        return (len(self.decode_ctx) + len(self.prefill_len)
                + len(self.chunk_spans) + len(self.spec_spans))


def decoding_only(batch: int, ctx: int) -> StageMix:
    return StageMix(decode_ctx=(ctx,) * batch)


def mixed(batch_decode: int, ctx: int, new_requests: int, l_in: int) -> StageMix:
    return StageMix(decode_ctx=(ctx,) * batch_decode,
                    prefill_len=(l_in,) * new_requests)


# ---------------------------------------------------------------------------
# Per-component cost records
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OpCost:
    """FLOPs + off-chip bytes of one layer component in one stage."""
    name: str
    flops: float
    weight_bytes: float
    act_bytes: float

    @property
    def bytes(self) -> float:
        return self.weight_bytes + self.act_bytes

    @property
    def opb(self) -> float:
        return self.flops / max(self.bytes, 1.0)

    def merged(self, other: "OpCost", name: Optional[str] = None) -> "OpCost":
        return OpCost(name or self.name, self.flops + other.flops,
                      self.weight_bytes + other.weight_bytes,
                      self.act_bytes + other.act_bytes)


def _gemm(name: str, tokens: int, d_in: int, d_out: int) -> OpCost:
    """Batched tokens × weight GEMM: weights read once (batching effect)."""
    flops = 2.0 * tokens * d_in * d_out
    w = BYTES * d_in * d_out
    a = BYTES * tokens * (d_in + d_out)
    return OpCost(name, flops, w, a)


# ---------------------------------------------------------------------------
# Attention (paper §II-B, §III-A)
# ---------------------------------------------------------------------------

def _kv_elem_bytes(hd: int, kv_quant: bool) -> float:
    """K-or-V bytes one cached token occupies per kv head: int8 caches
    stream 1-byte values plus a fp32 per-(token, kv-head) scale, halving
    the dominant decode stream (and doubling its Op/B) vs bf16."""
    return hd + 4.0 if kv_quant else float(BYTES * hd)


def attention_decode_cost(cfg: ModelConfig, ctx: int, *, window: int = 0,
                          kv_quant: bool = False) -> OpCost:
    """One decode sequence: 1 query token against `ctx` cached KV entries.

    GQA: per KV head, a (deg_grp × hd) Q slab hits (ctx × hd) K and V —
    a skinny GEMM. KV bytes dominate => Op/B ≈ 2·deg_grp, doubled by int8
    KV (``kv_quant``) since the streamed bytes halve at equal FLOPs.
    """
    eff_ctx = min(ctx, window) if window > 0 else ctx
    hd = cfg.resolved_head_dim
    kv, qpk = cfg.num_kv_heads, cfg.q_per_kv
    flops = 2.0 * kv * qpk * eff_ctx * hd * 2          # QK^T and PV
    kv_bytes = 2 * kv * eff_ctx * _kv_elem_bytes(hd, kv_quant)  # K + V read
    act = BYTES * kv * qpk * hd * 2                    # q in, out
    return OpCost("attn_decode", flops, 0.0, kv_bytes + act)


def attention_prefill_cost(cfg: ModelConfig, s: int, *, window: int = 0,
                           causal: bool = True,
                           kv_quant: bool = False) -> OpCost:
    """One prefill sequence of length s (triangular / banded score work)."""
    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    if window > 0:
        pairs = sum(min(i + 1, window) for i in range(s))
    elif causal:
        pairs = s * (s + 1) // 2
    else:
        pairs = s * s
    flops = 2.0 * h * pairs * hd * 2
    kv_bytes = 2 * cfg.num_kv_heads * s * _kv_elem_bytes(hd, kv_quant)
    act = BYTES * h * s * hd * 2
    return OpCost("attn_prefill", flops, 0.0, kv_bytes + act)


def attention_chunk_cost(cfg: ModelConfig, start: int, end: int, *,
                         window: int = 0, kv_quant: bool = False) -> OpCost:
    """One chunked-prefill sequence: queries [start, end) against the written
    [0, start) KV prefix plus the chunk's own causal K/V (banded when the
    layer has a sliding window — only the in-window prefix is read).

    Op/B interpolates between prefill (start=0: triangular, compute-bound)
    and decode (end=start+1: one query streaming the whole prefix,
    bandwidth-bound) — the knob the chunk budget turns; int8 KV
    (``kv_quant``) doubles the bandwidth end of the interpolation.
    """
    hd = cfg.resolved_head_dim
    h = cfg.num_heads
    if window > 0:
        pairs = sum(min(q + 1, window) for q in range(start, end))
        kv_read = min(end, window + (end - start))
    else:
        # sum_{q=start}^{end-1} (q+1) causal pairs
        pairs = (end * (end + 1) - start * (start + 1)) // 2
        kv_read = end
    flops = 2.0 * h * pairs * hd * 2
    kv_bytes = 2 * cfg.num_kv_heads * kv_read * _kv_elem_bytes(hd, kv_quant)
    act = BYTES * h * (end - start) * hd * 2
    return OpCost("attn_chunk", flops, 0.0, kv_bytes + act)


def qkv_proj_cost(cfg: ModelConfig, tokens: int) -> OpCost:
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    qkv = _gemm("qkv", tokens, d, (cfg.num_heads + 2 * cfg.num_kv_heads) * hd)
    proj = _gemm("proj", tokens, cfg.num_heads * hd, d)
    return qkv.merged(proj, "qkv+proj")


# ---------------------------------------------------------------------------
# FFN / MoE (paper §III-A)
# ---------------------------------------------------------------------------

def ffn_cost(cfg: ModelConfig, tokens: int, d_ff: Optional[int] = None) -> OpCost:
    f = d_ff if d_ff is not None else cfg.d_ff
    d = cfg.d_model
    mats = 3 if cfg.gated_ffn else 2
    flops = 2.0 * tokens * d * f * mats
    w = BYTES * mats * d * f
    a = BYTES * tokens * (2 * d + mats * f)
    return OpCost("ffn", flops, w, a)


def expert_cost(cfg: ModelConfig, tokens: int) -> OpCost:
    """One expert FFN processing `tokens` tokens. Op/B ≈ 2·tokens/3 for the
    weight-dominated regime (paper: ≥ 1 since multiple requests share experts)."""
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    mats = 3 if cfg.gated_ffn else 2
    flops = 2.0 * tokens * d * f * mats
    w = BYTES * mats * d * f
    a = BYTES * tokens * (2 * d + mats * f)
    return OpCost("expert", flops, w, a)


def expected_tokens_per_expert(cfg: ModelConfig, tokens: int) -> float:
    """Uniform-routing expectation (paper's workload model, §VI)."""
    m = cfg.moe
    return tokens * m.top_k / m.num_experts


def moe_cost(cfg: ModelConfig, tokens: int,
             counts: Optional[Sequence[int]] = None) -> OpCost:
    """Whole MoE layer. ``counts`` = per-expert token counts; default uniform.
    Weights of every *selected* expert are read once."""
    m = cfg.moe
    if counts is None:
        t_e = expected_tokens_per_expert(cfg, tokens)
        counts = [t_e] * m.num_experts
    total = OpCost("moe", 0.0, 0.0, 0.0)
    for c in counts:
        if c <= 0:
            continue
        total = total.merged(expert_cost(cfg, c), "moe")
    # router
    total = total.merged(_gemm("router", tokens, cfg.d_model, m.num_experts),
                         "moe")
    if m.num_shared_experts:
        total = total.merged(ffn_cost(cfg, tokens, m.d_ff_shared), "moe")
    return total


# ---------------------------------------------------------------------------
# Mamba (SSD) — TPU-adaptation addition (DESIGN.md §2)
# ---------------------------------------------------------------------------

def mamba_decode_cost(cfg: ModelConfig, batch: int) -> OpCost:
    """Single-token SSD state update per sequence: read+write (H,N,P) state.
    Op/B ≈ 2 — exactly the paper's Logic-PIM band."""
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    nheads = s.nheads(d)
    state = nheads * s.d_state * s.headdim
    flops = batch * (2.0 * 3 * state + 2 * d * (2 * d_in + 2 * s.d_state + nheads)
                     + 2 * d_in * d)
    proj_w = BYTES * (d * (2 * d_in + 2 * s.ngroups * s.d_state + nheads)
                      + d_in * d)
    state_bytes = BYTES * 2 * batch * 2 * state      # fp32 read + write
    return OpCost("mamba_decode", flops, proj_w, state_bytes)


def mamba_prefill_cost(cfg: ModelConfig, tokens: int) -> OpCost:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.d_inner(d)
    nheads = s.nheads(d)
    proj = _gemm("ssm_proj", tokens, d,
                 2 * d_in + 2 * s.ngroups * s.d_state + nheads)
    out = _gemm("ssm_out", tokens, d_in, d)
    # chunked SSD: intra-chunk (Q×Q per head) + state propagation
    q = s.chunk_size
    nchunks = max(tokens // q, 1)
    intra = 2.0 * nchunks * nheads * q * q * s.headdim * 2
    inter = 2.0 * tokens * nheads * s.d_state * s.headdim * 2
    ssd = OpCost("ssd", intra + inter, 0.0,
                 BYTES * tokens * d_in * 3)
    return proj.merged(out, "mamba_prefill").merged(ssd, "mamba_prefill")


# ---------------------------------------------------------------------------
# Whole-stage analysis (drives dispatch + Fig. 4 reproduction)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class LayerStageCost:
    """Costs of one layer kind in one stage, split by component so that
    attention co-processing (C3) can route each part separately."""
    kind: LayerKind
    components: Tuple[OpCost, ...]

    def total(self) -> OpCost:
        t = OpCost("total", 0.0, 0.0, 0.0)
        for c in self.components:
            t = t.merged(c, "total")
        return t


def layer_stage_cost(cfg: ModelConfig, kind: LayerKind, mix: StageMix,
                     counts: Optional[Sequence[int]] = None, *,
                     kv_quant: bool = False) -> LayerStageCost:
    comps: List[OpCost] = []
    T = mix.num_tokens
    window = cfg.sliding_window if kind.mixer == ATTN_LOCAL else 0
    if kind.mixer == MAMBA:
        if mix.decode_ctx:
            comps.append(mamba_decode_cost(cfg, len(mix.decode_ctx)))
        pre_tokens = (sum(mix.prefill_len)
                      + sum(e - s for s, e in mix.chunk_spans)
                      + sum(e - s for s, e in mix.spec_spans))
        if pre_tokens:
            comps.append(mamba_prefill_cost(cfg, pre_tokens))
    else:
        comps.append(qkv_proj_cost(cfg, T))
        dec = OpCost("attn_decode", 0.0, 0.0, 0.0)
        for ctx in mix.decode_ctx:
            dec = dec.merged(attention_decode_cost(cfg, ctx, window=window,
                                                   kv_quant=kv_quant),
                             "attn_decode")
        if mix.decode_ctx:
            comps.append(dec)
        pre = OpCost("attn_prefill", 0.0, 0.0, 0.0)
        for s in mix.prefill_len:
            pre = pre.merged(attention_prefill_cost(cfg, s, window=window,
                                                    kv_quant=kv_quant),
                             "attn_prefill")
        if mix.prefill_len:
            comps.append(pre)
        chk = OpCost("attn_chunk", 0.0, 0.0, 0.0)
        # spec-decode verify spans (PR 9) cost exactly like chunk spans —
        # attention_chunk_cost already interpolates from decode (end =
        # start+1) toward prefill as the span widens, which IS the raised
        # verify-stage Op/B the duplex planner must see
        for s0, s1 in (*mix.chunk_spans, *mix.spec_spans):
            chk = chk.merged(attention_chunk_cost(cfg, s0, s1,
                                                  window=window,
                                                  kv_quant=kv_quant),
                             "attn_chunk")
        if mix.chunk_spans or mix.spec_spans:
            comps.append(chk)
        if kind.mixer == ATTN_CROSS:
            # decoder cross-attention reads encoder KV: decode ≈ attn_decode
            comps.append(dataclasses.replace(dec, name="cross_attn"))
    if kind.ffn == DENSE:
        comps.append(ffn_cost(cfg, T))
    elif kind.ffn == MOE:
        comps.append(moe_cost(cfg, T, counts))
    return LayerStageCost(kind, tuple(comps))


def stage_cost_breakdown(cfg: ModelConfig, mix: StageMix,
                         counts: Optional[Sequence[int]] = None, *,
                         kv_quant: bool = False) -> Dict[str, OpCost]:
    """Aggregate component costs over all layers of the model (Fig. 4(a))."""
    agg: Dict[str, OpCost] = {}
    for kind in cfg.layer_kinds():
        lc = layer_stage_cost(cfg, kind, mix, counts, kv_quant=kv_quant)
        for c in lc.components:
            key = c.name
            agg[key] = agg[key].merged(c) if key in agg else c
    # LM head (per generated token: decode seqs + 1 per prefill seq; a
    # verify span samples EVERY position — end-start outputs per row)
    out_tokens = (len(mix.decode_ctx) + len(mix.prefill_len)
                  + sum(e - s for s, e in mix.spec_spans))
    agg["lm_head"] = _gemm("lm_head", out_tokens, cfg.d_model, cfg.vocab_size)
    return agg
