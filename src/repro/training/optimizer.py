"""AdamW with decoupled weight decay + global-norm clipping (pure pytree).

Optimizer state mirrors the parameter tree, so it inherits the parameters'
logical sharding axes — FSDP over `data` shards first/second moments too
(required to fit mistral-large-123b's ~1.23 TB of param+optimizer state).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    adam_dtype: Any = jnp.float32
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.learning_rate * warm * scale


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, cfg.adam_dtype), params)
    return {"mu": zeros,
            "nu": jax.tree_util.tree_map(jnp.copy, zeros),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, state, cfg: OptConfig, step=None
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    lr = lr_schedule(cfg, step if step is not None else count)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(cfg.adam_dtype)
        mu = b1 * mu + (1 - b1) * g32
        nu = b2 * nu + (1 - b2) * jnp.square(g32)
        mu_hat = mu / bc1
        nu_hat = nu / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        return p_new.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_mu = jax.tree_util.tree_leaves(state["mu"])
    flat_nu = jax.tree_util.tree_leaves(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a)
        new_mu.append(b)
        new_nu.append(c)
    unflatten = jax.tree_util.tree_unflatten
    new_state = {"mu": unflatten(treedef, new_mu),
                 "nu": unflatten(treedef, new_nu), "count": count}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return unflatten(treedef, new_p), new_state, metrics
