"""Deterministic, resumable synthetic data pipeline.

Every batch is addressable by (seed, step): restart/elastic-rescale resumes
bit-exactly from the checkpointed step with no pipeline state beyond one
integer. Sequences are Zipf-distributed token ids with a simple Markov blend
so the LM loss actually decreases (examples/train_moe_100m.py shows ~100
steps of real learning).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2


class SyntheticLMData:
    """Index-based pipeline: ``batch_at(step)`` is a pure function."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        # fixed per-seed Markov successor table (makes data learnable)
        rng = np.random.default_rng(cfg.seed)
        self._succ = rng.integers(0, cfg.vocab_size,
                                  size=(cfg.vocab_size,), dtype=np.int32)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        draw = rng.zipf(c.zipf_a, size=(c.global_batch, c.seq_len))
        base = (draw % (c.vocab_size - 1)).astype(np.int32)
        tokens = np.empty_like(base)
        tokens[:, 0] = base[:, 0]
        # 75% Markov successor / 25% noise: learnable bigram structure
        use_succ = rng.random((c.global_batch, c.seq_len)) < 0.75
        for t in range(1, c.seq_len):
            tokens[:, t] = np.where(use_succ[:, t],
                                    self._succ[tokens[:, t - 1]], base[:, t])
        return {"tokens": tokens}

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
