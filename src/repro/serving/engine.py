"""Continuous-batching serving engine with Duplex dispatch (C1–C3).

Stage loop (paper §II-C / §V):

  * The scheduler forms a stage: decode sequences + (possibly) admitted
    prefill sequences (mixed stage).
  * C1: ``core/dispatch.plan_stage`` computes each component's Op/B and
    selects its execution path; the engine renders that into ExecutionPlans
    the jitted step functions are traced under.
  * C2: MoE layers in decoding-heavy stages run the *duplex* implementation —
    the partitioner's statically-bucketed ``k_cold`` picks how many experts go
    through the bandwidth (gather-GEMV) path; which experts is decided
    dynamically per layer from the actual router counts inside the step.
    With kernels on, both paths are *ragged* (``moe_ragged``): live counts
    ride into the scalar-prefetch kernels, dead token blocks cost no DMAs or
    FLOPs, and the engine sizes ``c_hot`` to a bucketed live-block count so
    the token grid is a stable jit key.
  * C3: the mixed stage runs decode-sequence attention through the
    bandwidth-path decode kernel and prefill attention through the
    compute-path blockwise kernel. On Duplex hardware the two run
    concurrently on Logic-PIM/xPU; on a TPU they time-share the chip — the
    routing (which kernel, which layout) is the paper's mechanism, the
    concurrency benefit is modeled in ``sim/`` (DESIGN.md §2).

jit discipline: step functions are cached per static key (k_cold bucket,
prefill shape bucket; paged decode additionally batch/live-page buckets) so
continuous batching never recompiles in steady state.

KV layouts: ``kv_layout="dense"`` decodes over all slots against the
``max_slots × max_len`` cache (seed behavior); ``kv_layout="paged"`` decodes
a gathered active-slot batch against a shared KV page pool, so per-stage HBM
traffic scales with occupancy × live context (ROADMAP.md "DESIGN: paged KV
cache").
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN_LOCAL, MAMBA, ModelConfig
from repro.core.costmodel import DUPLEX
from repro.core.dispatch import plan_stage
from repro.core.execution import ExecutionPlan, execution_plan
from repro.core.partition import DuplexPlanner, build_luts
from repro.models.model import decode_step, init_cache, prefill
from repro.serving.kvmanager import KVManager
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, sample
from repro.serving.scheduler import ContinuousBatchingScheduler, StageDecision


def _bucket(n: int, buckets) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


def _pow2_buckets(n_max: int) -> Tuple[int, ...]:
    out = []
    b = 1
    while b < n_max:
        out.append(b)
        b *= 2
    out.append(n_max)
    return tuple(out)


def _pow2_ceil(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length()


@dataclass
class StageReport:
    stage_index: int
    is_mixed: bool
    num_decode: int
    num_prefill: int
    k_cold: int
    bandwidth_flop_fraction: float
    wall_time: float
    # K+V bytes the decode attention path streams this stage (all attention
    # layers). Dense: max_slots × max_len regardless of occupancy. Paged:
    # live pages of the active slots only.
    kv_bytes_streamed: int = 0
    # MoE weight+activation bytes the decode-stage expert kernels stream
    # (all MoE layers, modeled from the stage's expected routing counts —
    # the planner's seeded stream rescaled to the decode token count).
    # Padded kernels execute the full capacity grid; ragged kernels execute
    # live token blocks only.
    moe_bytes_streamed: int = 0
    moe_flops_live: int = 0       # FLOPs over live (routed) token blocks
    moe_flops_padded: int = 0     # FLOPs the capacity-padded path would burn


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int,
                 max_len: int, use_duplex: bool = True,
                 use_kernels: bool = False, kv_quant: bool = False,
                 moe_ragged: bool = True, moe_c_block: int = 256,
                 preemption: str = "none", kv_layout: str = "dense",
                 kv_page_size: int = 64, kv_num_pages: Optional[int] = None,
                 sampling: SamplingParams = SamplingParams(),
                 max_prefill_seqs: int = 4, max_prefill_tokens: int = 8192,
                 prefill_len_buckets: Tuple[int, ...] = (64, 128, 256, 512,
                                                         1024, 2048, 4096),
                 seed: int = 0):
        assert not cfg.is_encoder_decoder, \
            "engine serves decoder-only LMs; enc-dec is exercised via serve_step"
        assert preemption in ("none", "migrate", "recompute")
        self.preemption = preemption
        self.preemptions = 0
        self.cfg = cfg
        self.params = params
        self.kv = KVManager(cfg, max_slots, max_len, kv_quant=kv_quant,
                            layout=kv_layout, page_size=kv_page_size,
                            num_pages=kv_num_pages)
        self.paged = self.kv.paged
        if self.paged and preemption != "none":
            raise NotImplementedError(
                "preemption gathers dense slot rows; paged eviction is "
                "page-table surgery and not implemented yet")
        self.scheduler = ContinuousBatchingScheduler(
            max_prefill_seqs=max_prefill_seqs,
            max_prefill_tokens=max_prefill_tokens)
        self.sampling = sampling
        self.use_duplex = use_duplex and cfg.moe is not None
        self.use_kernels = use_kernels
        # ragged MoE kernels need the count-threaded duplex path + Pallas
        # (the XLA grouped fallback is inherently capacity-padded).
        self.moe_ragged = bool(moe_ragged and use_kernels and self.use_duplex)
        self.moe_c_block = moe_c_block
        self.prefill_len_buckets = tuple(
            b for b in prefill_len_buckets if b <= max_len) or (max_len,)
        self.seq_buckets = tuple(sorted({1, 2, max_prefill_seqs}))
        self.planner: Optional[DuplexPlanner] = None
        if self.use_duplex:
            # the xPU LUT models what the hot kernel executes: ragged →
            # block-quantized live tokens; padded → the full capacity grid,
            # weights re-streamed once per c_block token block either way.
            ch, _, cb = self._moe_caps(max_slots, 0)
            if self.moe_ragged:
                hot_kw = dict(hot_block=cb)
            else:
                hot_kw = dict(hot_block=cb, hot_capacity=ch)
            lut_x, lut_p = build_luts(DUPLEX, cfg.d_model,
                                      cfg.moe.d_ff_expert,
                                      max_tokens=max(4 * max_slots, 512),
                                      **hot_kw)
            self.planner = DuplexPlanner(lut_x, lut_p, cfg.moe.num_experts)
        # decode-attention streamed-bytes accounting (K+V only; mamba mixers
        # hold O(1) state and cross-attn KV is written once, both excluded).
        # Dense streams each layer's whole buffer — max_len for full
        # attention, the ring (window+1) for ATTN_LOCAL.
        per_tok = (2 * cfg.num_kv_heads * cfg.resolved_head_dim *
                   jnp.dtype(cfg.dtype).itemsize)
        n_attn = 0
        dense_tokens_per_slot = 0
        for seg in cfg.segments:
            for kind in seg.pattern:
                if kind.mixer == MAMBA:
                    continue
                n_attn += seg.repeats
                if kind.mixer == ATTN_LOCAL and cfg.sliding_window > 0:
                    dense_tokens_per_slot += seg.repeats * (
                        min(max_len, cfg.sliding_window) + 1)
                else:
                    dense_tokens_per_slot += seg.repeats * max_len
        self._kv_bytes_per_token = per_tok * n_attn
        self._dense_kv_bytes_per_stage = (max_slots * per_tok *
                                          dense_tokens_per_slot)
        # MoE streamed-bytes accounting: layer count + GEMM matrices per
        # expert FFN (3 SwiGLU / 2 classic) for the traffic model.
        from repro.configs.base import MOE
        self._moe_layers = sum(seg.repeats
                               for seg in cfg.segments
                               for kind in seg.pattern if kind.ffn == MOE)
        self._moe_mats = 3 if cfg.gated_ffn else 2
        self._param_itemsize = jnp.dtype(cfg.param_dtype).itemsize
        self._key = jax.random.PRNGKey(seed)
        self._tokens = np.zeros((max_slots,), np.int32)   # last token per slot
        self._slot_req: Dict[int, Request] = {}
        self._decode_fns: Dict[int, callable] = {}
        self._paged_decode_fns: Dict[Tuple[int, int, int], callable] = {}
        self._prefill_fns: Dict[Tuple[int, int], callable] = {}
        # paged decode jit keys: (batch bucket, live-page bucket) — powers of
        # two so steady-state continuous batching never recompiles.
        self.decode_bs_buckets = _pow2_buckets(max_slots)
        if self.paged:
            self.pages_buckets = _pow2_buckets(self.kv.max_pages_per_slot)
        self._stage_idx = 0
        self.reports: List[StageReport] = []

    # ------------------------------------------------------------------ jits
    def _moe_caps(self, T: int, k_cold: int) -> Tuple[int, int, int]:
        """(c_hot, c_cold, c_block) for a decode stage of T (already
        bucketed) tokens. The hot capacity snaps up to a power-of-two count
        of c_block-sized token blocks — the stage's *live-block bucket* —
        so the ragged kernel's token-block grid is a stable jit key and
        steady state never recompiles."""
        from repro.core.duplex_moe import default_capacities
        if self.cfg.moe is None:
            return 0, 0, self.moe_c_block
        ch, cc = default_capacities(T, self.cfg.moe, k_cold)
        cb = min(self.moe_c_block, _pow2_ceil(ch))
        blocks = _pow2_ceil(-(-ch // cb))
        return blocks * cb, cc, cb

    def _moe_plan(self, k_cold: int, c_hot: int, c_cold: int,
                  c_block: int) -> ExecutionPlan:
        # the ragged kernels live on the count-threaded duplex path, so keep
        # it selected even at k_cold == 0 (all experts hot, all ragged).
        use_duplex_impl = k_cold > 0 or self.moe_ragged
        return ExecutionPlan(
            moe_impl="duplex" if use_duplex_impl else "grouped",
            k_cold=k_cold,
            c_hot=c_hot if use_duplex_impl else None,
            c_cold=c_cold if use_duplex_impl else None,
            moe_ragged=self.moe_ragged, moe_c_block=c_block,
            use_kernels=self.use_kernels)

    def _decode_fn(self, k_cold: int, c_hot: int, c_cold: int, c_block: int):
        key = (k_cold, c_hot, c_cold)
        if key not in self._decode_fns:
            cfg = self.cfg
            plan = self._moe_plan(k_cold, c_hot, c_cold, c_block)

            @jax.jit
            def fn(params, tokens, cache, key):
                with execution_plan(plan):
                    logits, new_cache = decode_step(params, cfg, tokens, cache)
                nxt = sample(logits, key, self.sampling)
                return nxt, new_cache

            self._decode_fns[key] = fn
        return self._decode_fns[key]

    def _paged_decode_fn(self, k_cold: int, c_hot: int, c_cold: int,
                         c_block: int, n_batch: int, n_pages: int):
        """Paged decode step over a gathered active-slot batch. Static key =
        (k_cold, hot/cold capacities, batch bucket, live-page bucket): both
        the kv grid and the MoE token-block grid are trimmed to the stage's
        bucketed live work, not the configured maxima."""
        key = (k_cold, c_hot, c_cold, n_batch, n_pages)
        if key not in self._paged_decode_fns:
            cfg = self.cfg
            plan = self._moe_plan(k_cold, c_hot, c_cold, c_block)

            @jax.jit
            def fn(params, tokens, cache, lengths, block_tables, key_):
                with execution_plan(plan):
                    logits, new_cache = decode_step(
                        params, cfg, tokens, cache,
                        attn_ctx={"lengths": lengths,
                                  "block_tables": block_tables})
                nxt = sample(logits, key_, self.sampling)
                return nxt, new_cache

            self._paged_decode_fns[key] = fn
        return self._paged_decode_fns[key]

    def _prefill_fn(self, n_seqs: int, seq_len: int):
        key = (n_seqs, seq_len)
        if key not in self._prefill_fns:
            cfg = self.cfg
            max_len = self.kv.max_len
            # mixed-stage prefill is the high-Op/B side: grouped MoE +
            # blockwise (compute-path) attention, per C1/C3.
            plan = ExecutionPlan(moe_impl="grouped",
                                 use_kernels=self.use_kernels)

            kv_quant = self.kv.kv_quant

            @jax.jit
            def fn(params, tokens, true_len, skey):
                with execution_plan(plan):
                    cache = init_cache(cfg, n_seqs, max_len,
                                       kv_quant=kv_quant)
                    logits, new_cache = prefill(params, cfg,
                                                {"tokens": tokens}, cache,
                                                true_len)
                nxt = sample(logits, skey, self.sampling)
                return nxt, new_cache

            self._prefill_fns[key] = fn
        return self._prefill_fns[key]

    # ------------------------------------------------------------------ api
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def _next_key(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _maybe_preempt(self) -> None:
        """SVIII-C: if a fresh request is starving with zero free slots,
        evict a running request (migrate its KV to host, or drop it for
        later recomputation) to reclaim capacity."""
        from repro.serving import preemption as pre
        if self.preemption == "none" or self.kv.free_slots > 0:
            return
        q = self.scheduler.queue
        if not q or q[0].was_preempted:
            return                      # nothing starving / avoid thrash
        victim = pre.pick_victim(self.scheduler.running)
        if victim is None:
            return
        self._slot_req.pop(victim.slot, None)
        if self.preemption == "migrate":
            pre.migrate_out(self.kv, victim)
        else:
            pre.recompute_out(self.kv, victim)
        self.scheduler.resubmit_preempted(victim)
        self.preemptions += 1

    def _admit_restored(self, req, tnow: float) -> None:
        """Re-admit a migrated request: scatter its host-saved KV back into
        a fresh slot and resume decoding (no recompute)."""
        from repro.serving import preemption as pre
        slot = self.kv.allocate()
        pre.restore_slot(self.kv, slot, req.saved_cache)
        req.saved_cache = None
        req.slot = slot
        self._slot_req[slot] = req
        self._tokens[slot] = req.output[-1]
        req.state = RequestState.DECODE

    def step(self, now: Optional[float] = None) -> Optional[StageReport]:
        """Run one continuous-batching stage. Returns None when idle."""
        t0 = time.monotonic()
        self._maybe_preempt()
        free = self.kv.free_slots
        if self.paged:
            # admission backpressure for oversubscribed pools: only admit
            # when the pool can still hold one worst-case prompt plus a page
            # of growth per running sequence. Running sequences can still
            # exhaust a badly undersized pool (ensure_len raises — there is
            # no paged preemption yet), but admissions won't cause it.
            reserve = (len(self.scheduler.running) +
                       self.kv.max_pages_per_slot)
            if self.kv.free_pages < reserve:
                free = 0
        decision = self.scheduler.next_stage(free)
        if decision is None:
            return None
        mix = decision.mix()
        k_cold = 0
        if self.use_duplex and mix.num_tokens > 0:
            # planner input: expected per-expert counts for this stage's token
            # count (uniform routing, paper §VI); the jitted step re-ranks
            # experts from *actual* counts — only the width is static.
            m = self.cfg.moe
            rng = np.random.default_rng(self._stage_idx)
            counts = rng.multinomial(mix.num_tokens * m.top_k,
                                     np.full(m.num_experts,
                                             1.0 / m.num_experts))
            k_cold = self.planner.k_cold_static(counts)
        splan = plan_stage(self.cfg, mix) if mix.num_tokens else None

        # ---- decode half (bandwidth path). Dense: runs over all slots —
        # outputs of inactive slots are discarded, their cache is overwritten
        # on reuse, and their dead KV is streamed every stage. Paged: runs
        # over a gathered active-slot batch bucket; the kv grid is trimmed to
        # the stage's bucketed max live pages, so HBM traffic scales with
        # occupancy × live context instead of max_slots × max_len.
        kv_bytes = 0
        decode_tokens = 0              # rows the decode step pushes through MoE
        moe_caps = None
        if decision.decoding and self.paged:
            page = self.kv.page_size
            slots = [r.slot for r in decision.decoding]
            live_pages = []                # per-slot pages after this write
            for s in slots:
                target = min(int(self.kv.lens[s]) + 1, self.kv.max_len)
                self.kv.ensure_len(s, target)
                live_pages.append(-(-target // page))
            kv_bytes = sum(live_pages) * page * self._kv_bytes_per_token
            nb = _bucket(len(slots), self.decode_bs_buckets)
            mp = _bucket(max(live_pages), self.pages_buckets)
            tokens = np.zeros((nb, 1), np.int32)
            lengths = np.zeros((nb,), np.int32)   # pad rows: len 0 -> null page
            bt = np.zeros((nb, mp), np.int32)
            for i, s in enumerate(slots):
                tokens[i, 0] = self._tokens[s]
                lengths[i] = self.kv.lens[s]
                bt[i] = self.kv.block_tables[s, :mp]
            decode_tokens = nb
            moe_caps = self._moe_caps(nb, k_cold)
            fn = self._paged_decode_fn(k_cold, *moe_caps, nb, mp)
            nxt, self.kv.cache = fn(self.params, jnp.asarray(tokens),
                                    self.kv.cache, jnp.asarray(lengths),
                                    jnp.asarray(bt), self._next_key())
            nxt = np.asarray(nxt)
            tnow = now if now is not None else time.monotonic()
            for i, r in enumerate(decision.decoding):
                tok = int(nxt[i])
                self._tokens[r.slot] = tok
                r.record_token(tok, tnow)
            self.kv.lens[np.asarray(slots)] += 1
        elif decision.decoding:
            kv_bytes = self._dense_kv_bytes_per_stage
            # dense decode runs over ALL slots (inactive rows discarded), so
            # the MoE layers see max_slots tokens regardless of occupancy.
            decode_tokens = self.kv.max_slots
            moe_caps = self._moe_caps(decode_tokens, k_cold)
            fn = self._decode_fn(k_cold, *moe_caps)
            toks = jnp.asarray(self._tokens)[:, None]
            nxt, self.kv.cache = fn(self.params, toks, self.kv.cache,
                                    self._next_key())
            nxt = np.asarray(nxt)
            tnow = now if now is not None else time.monotonic()
            for r in decision.decoding:
                tok = int(nxt[r.slot])
                self._tokens[r.slot] = tok
                r.record_token(tok, tnow)

        # ---- prefill half (compute path), mixed stages only
        tnow0 = now if now is not None else time.monotonic()
        restored = [r for r in decision.admitted
                    if r.saved_cache is not None]
        fresh = [r for r in decision.admitted if r.saved_cache is None]
        for r in restored:                       # migrated-back requests
            self._admit_restored(r, tnow0)
        if fresh:
            n_b = _bucket(len(fresh), self.seq_buckets)
            # recompute-preempted requests re-prefill prompt + generated
            seqs = [list(r.prompt) + list(r.output) for r in fresh]
            max_l = max(len(sq) for sq in seqs)
            l_b = _bucket(max_l, self.prefill_len_buckets)
            tokens = np.zeros((n_b, l_b), np.int32)
            true_len = np.zeros((n_b,), np.int32)
            for i, sq in enumerate(seqs):
                tokens[i, :len(sq)] = sq[:l_b]
                true_len[i] = min(len(sq), l_b)
            fn = self._prefill_fn(n_b, l_b)
            nxt, local_cache = fn(self.params, jnp.asarray(tokens),
                                  jnp.asarray(true_len), self._next_key())
            nxt = np.asarray(nxt)
            slots = [self.kv.allocate() for _ in fresh]
            take = jnp.asarray(range(len(slots)), dtype=jnp.int32)
            local = [jax.tree_util.tree_map(lambda a: a[:, take], seg)
                     for seg in local_cache]
            if self.paged:
                self.kv.scatter_paged(local, slots,
                                      [int(t) for t in true_len[:len(slots)]])
            else:
                self.kv.scatter(local, slots)
            tnow = now if now is not None else time.monotonic()
            for i, (r, s) in enumerate(zip(fresh, slots)):
                r.slot = s
                self._slot_req[s] = r
                tok = int(nxt[i])
                self._tokens[s] = tok
                r.record_token(tok, tnow)

        # ---- retire
        for r in decision.admitted + decision.decoding:
            if r.done and r.slot >= 0:
                self.kv.free(r.slot)
                self._slot_req.pop(r.slot, None)
        self.scheduler.commit_stage(decision)

        # ---- MoE streamed-bytes / padded-vs-live FLOP accounting for the
        # decode half (the count-threaded duplex path): counts drawn from the
        # planner's seeded stream, rescaled to the decode step's token count
        # (identical to the planner vector whenever the totals coincide).
        moe_bytes = moe_flops_live = moe_flops_padded = 0
        if (self.use_duplex and decode_tokens and self._moe_layers
                and (k_cold > 0 or self.moe_ragged)):
            from repro.core.duplex_moe import moe_traffic_model
            m = self.cfg.moe
            rng = np.random.default_rng(self._stage_idx)
            dcounts = rng.multinomial(decode_tokens * m.top_k,
                                      np.full(m.num_experts,
                                              1.0 / m.num_experts))
            ch, cc, cb = moe_caps
            stats = moe_traffic_model(dcounts, k_cold=k_cold, c_hot=ch,
                                      c_cold=cc, d_model=self.cfg.d_model,
                                      d_ff=m.d_ff_expert, c_block=cb,
                                      itemsize=self._param_itemsize,
                                      mats=self._moe_mats)
            L = self._moe_layers
            which = "ragged" if self.moe_ragged else "padded"
            moe_bytes = stats[f"{which}_bytes"] * L
            moe_flops_live = stats["ragged_flops"] * L
            moe_flops_padded = stats["padded_flops"] * L

        report = StageReport(
            stage_index=self._stage_idx, is_mixed=decision.is_mixed,
            num_decode=len(decision.decoding),
            num_prefill=len(decision.admitted), k_cold=k_cold,
            bandwidth_flop_fraction=(splan.bandwidth_fraction()
                                     if splan else 0.0),
            wall_time=time.monotonic() - t0,
            kv_bytes_streamed=int(kv_bytes),
            moe_bytes_streamed=int(moe_bytes),
            moe_flops_live=int(moe_flops_live),
            moe_flops_padded=int(moe_flops_padded))
        self.reports.append(report)
        self._stage_idx += 1
        return report

    def run(self, requests: List[Request], *, max_stages: int = 10_000
            ) -> List[Request]:
        for r in requests:
            self.submit(r)
        stages = 0
        while self.scheduler.has_work and stages < max_stages:
            if self.step() is None:
                break
            stages += 1
        return requests
