"""Cluster/system model: devices, interconnect, model distribution (§III/§VI).

Distribution follows the paper's methodology (Fig. 3): tensor parallelism for
non-expert FC layers within a node, data parallelism across nodes; expert
parallelism for MoE (or expert tensor parallelism under C4 "+ET"); attention
distributed by request/head parallelism.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.costmodel import (DeviceSpec, DuplexSpec, IB_BW, NVLINK_BW)

BYTES = 2


@dataclass(frozen=True)
class SystemSpec:
    """A serving system: homogeneous devices in nodes."""
    name: str
    nodes: int
    devs_per_node: int
    device: object                     # DeviceSpec (GPU) or DuplexSpec
    nvlink_bw: float = NVLINK_BW
    ib_bw: float = IB_BW
    # expert distribution: "ep" (paper default) or "et" (C4: TP within node)
    moe_dist: str = "ep"

    @property
    def n_dev(self) -> int:
        return self.nodes * self.devs_per_node

    @property
    def is_duplex(self) -> bool:
        return isinstance(self.device, DuplexSpec)

    def xpu(self) -> DeviceSpec:
        return self.device.xpu if self.is_duplex else self.device

    def pim(self) -> Optional[DeviceSpec]:
        return self.device.pim if self.is_duplex else None

    @property
    def mem_capacity(self) -> float:
        dev = self.device
        cap = dev.mem_capacity if hasattr(dev, "mem_capacity") else 0.0
        return self.n_dev * cap


def weight_bytes(cfg: ModelConfig) -> float:
    return BYTES * cfg.param_count()


def kv_bytes_per_token(cfg: ModelConfig) -> float:
    """KV-cache bytes one context token costs (all layers)."""
    hd = cfg.resolved_head_dim
    n_attn = sum(1 for k in cfg.layer_kinds()
                 if k.mixer != "mamba")
    return BYTES * 2 * cfg.num_kv_heads * hd * n_attn


def max_batch_size(system: SystemSpec, cfg: ModelConfig, max_ctx: int,
                   *, weight_copies: int = 1) -> int:
    """Requests that fit after weights (paper §III-B / Fig. 5(c))."""
    free = system.mem_capacity - weight_copies * weight_bytes(cfg)
    per_req = kv_bytes_per_token(cfg) * max_ctx
    return max(int(free / per_req), 0)
