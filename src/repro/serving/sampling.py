"""Token sampling: greedy / temperature / top-k (jit-friendly)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SamplingParams:
    temperature: float = 0.0     # 0 => greedy
    top_k: int = 0               # 0 => full distribution
    top_p: float = 1.0           # nucleus sampling threshold


def sample(logits, key, params: SamplingParams):
    """logits: (B, 1, V) -> (B,) int32 next tokens."""
    logits = logits[:, -1, :].astype(jnp.float32)
    if params.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / params.temperature
    if params.top_k > 0:
        top, _ = jax.lax.top_k(logits, params.top_k)
        thresh = top[:, -1:]
        logits = jnp.where(logits < thresh, -jnp.inf, logits)
    if params.top_p < 1.0:
        # nucleus: keep the smallest prefix of the sorted distribution whose
        # cumulative mass reaches top_p (always keep the argmax)
        sort_idx = jnp.argsort(-logits, axis=-1)
        sorted_logits = jnp.take_along_axis(logits, sort_idx, axis=-1)
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep_sorted = (cum - probs) < params.top_p   # prefix incl. first
        keep = jnp.zeros_like(keep_sorted).at[
            jnp.arange(logits.shape[0])[:, None], sort_idx].set(keep_sorted)
        logits = jnp.where(keep, logits, -jnp.inf)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
