"""Fig. 11: normalized throughput of Duplex / Duplex+PE / Duplex+PE+ET vs
GPU and 2xGPU for Mixtral, GLaM, Grok1 over (L_in, L_out) and batch size.

Reproduces: Duplex up to ~2.5x GPU, +PE ~1.04x over Duplex, +PE+ET up to
~2.67x GPU; Grok1 gains least (2-node IB communication overhead).
"""
from __future__ import annotations

from typing import Dict, List

from repro.sim.engine_sim import simulate
from repro.sim.paper_models import GLAM, GROK1, MIXTRAL
from repro.sim.specs import default_system
from repro.sim.workload import gaussian_requests

from benchmarks.common import fresh

VARIANTS = [("gpu", "gpu"), ("gpu2x", "gpu"), ("duplex", "duplex"),
            ("duplex", "duplex_pe"), ("duplex_et", "duplex_pe_et")]


def run(quick: bool = True) -> List[Dict]:
    rows = []
    models = (MIXTRAL,) if quick else (MIXTRAL, GLAM, GROK1)
    cases = [(256, 256, 32), (1024, 1024, 64)] if quick else \
        [(256, 256, 32), (1024, 1024, 64), (4096, 4096, 128)]
    for cfg in models:
        for l_in, l_out, batch in cases:
            n_req = max(2 * batch, 48) if quick else 4 * batch
            proto = gaussian_requests(n_req, l_in, min(l_out, 256 if quick
                                                       else l_out), seed=11)
            base = None
            for kind, policy in VARIANTS:
                reqs = fresh(proto)
                r = simulate(default_system(cfg, kind), cfg, policy, reqs,
                             max_batch=batch)
                if kind == "gpu" and policy == "gpu":
                    base = r.throughput
                rows.append({
                    "model": cfg.name, "l_in": l_in, "l_out": l_out,
                    "batch": batch, "system": kind, "policy": policy,
                    "tok_per_s": r.throughput,
                    "speedup_vs_gpu": r.throughput / base,
                })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows("fig11_throughput", run(quick=False))
