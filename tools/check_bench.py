"""Benchmark trend gate: compare fresh benchmark JSONs against baselines.

  PYTHONPATH=src python -m benchmarks.run --out-dir bench-json --only ...
  python tools/check_bench.py --dir bench-json
  python tools/check_bench.py --dir bench-json --update   # re-seed baselines

The perf-trajectory JSONs (``benchmarks/run.py --out-dir``) were upload-only
artifacts: a regression changed the numbers and nobody failed. This gate
compares each current ``<name>.json`` against the committed
``benchmarks/baselines/BENCH_<name>.json``:

  * identity fields (strings, booleans, None) must match exactly — a row's
    ``policy``/``case``/``drain_clean`` flipping is a semantic break, not
    noise;
  * numeric fields must land inside a tolerance band
    (``|cur - base| <= abs + rel * |base|``) — the workloads are seeded and
    virtual-timed, so drift beyond the band means the code changed
    behavior, not the machine changed speed;
  * wall-clock-ish fields (``t_*``, ``*_s``, ``tokens_s*``, ...) are
    SKIPPED — CI machines vary and those belong to the artifact trail, not
    the gate.

Baselines are re-seeded deliberately with ``--update`` when a PR moves the
numbers on purpose; the diff then shows exactly what moved, by how much.

Cross-commit history (PR 9): a fixed baseline only catches drift against
ONE anchored run — slow creep that re-seeds the baseline each PR never
trips it. With ``--history DIR``, every run appends one JSONL record
(timestamp, commit, rows) to ``DIR/<name>.jsonl`` and each numeric field
is additionally gated against the ROLLING MEDIAN of the last ``--history-n``
recorded runs: the band anchors to recent reality instead of a hand-picked
snapshot, and the median shrugs off a single outlier run. The history gate
arms only once ``--history-min`` records exist, so fresh benchmarks pass
while their trail accumulates. The current run is appended AFTER gating —
a drifting run still leaves its record, but never vouches for itself.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import shutil
import subprocess
import sys
import time

DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), os.pardir,
                                 "benchmarks", "baselines")
# wall-clock-dependent fields: machine speed, not code behavior
SKIP_FIELD = re.compile(r"(^t_|_time$|^time_|_s$|_ms$|tokens_s|wall)")


def compare_rows(name, base_rows, cur_rows, *, rel, abs_tol):
    problems = []
    if len(base_rows) != len(cur_rows):
        return [f"{name}: row count {len(cur_rows)} != baseline "
                f"{len(base_rows)}"]
    for i, (b, c) in enumerate(zip(base_rows, cur_rows)):
        for key, bv in b.items():
            if key not in c:
                problems.append(f"{name}[{i}].{key}: missing from current")
                continue
            cv = c[key]
            if isinstance(bv, bool) or bv is None or isinstance(bv, str):
                if cv != bv:
                    problems.append(
                        f"{name}[{i}].{key}: {cv!r} != baseline {bv!r}")
            elif isinstance(bv, (int, float)):
                if SKIP_FIELD.search(key):
                    continue
                if not isinstance(cv, (int, float)) or \
                        abs(cv - bv) > abs_tol + rel * abs(bv):
                    problems.append(
                        f"{name}[{i}].{key}: {cv} outside band around "
                        f"baseline {bv} (rel={rel}, abs={abs_tol})")
    return problems


def _median(vals):
    s = sorted(vals)
    n = len(s)
    mid = n // 2
    return s[mid] if n % 2 else (s[mid - 1] + s[mid]) / 2.0


def _load_history(path, last_n):
    """Last ``last_n`` well-formed records of a JSONL history file."""
    if not os.path.exists(path):
        return []
    records = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                continue            # a torn append never poisons the gate
            if isinstance(rec, dict) and isinstance(rec.get("rows"), list):
                records.append(rec)
    return records[-last_n:]


def compare_history(name, records, cur_rows, *, rel, abs_tol, min_runs):
    """Gate each numeric field against the rolling median of its history.

    Same identity/tolerance philosophy as :func:`compare_rows`, but the
    anchor is the median of the last N recorded runs instead of the single
    committed baseline. Inactive until ``min_runs`` records exist."""
    problems = []
    if len(records) < min_runs:
        return problems
    for i, c in enumerate(cur_rows):
        for key, cv in c.items():
            if isinstance(cv, bool) or not isinstance(cv, (int, float)):
                continue
            if SKIP_FIELD.search(key):
                continue
            series = []
            for rec in records:
                rows = rec["rows"]
                if i < len(rows) and isinstance(rows[i], dict):
                    hv = rows[i].get(key)
                    if isinstance(hv, (int, float)) \
                            and not isinstance(hv, bool):
                        series.append(hv)
            if len(series) < min_runs:
                continue
            med = _median(series)
            if abs(cv - med) > abs_tol + rel * abs(med):
                problems.append(
                    f"{name}[{i}].{key}: {cv} outside band around rolling "
                    f"median {med} of last {len(series)} runs "
                    f"(rel={rel}, abs={abs_tol})")
    return problems


def append_history(history_dir, name, cur):
    """Append this run's rows (stamped with time + best-effort commit) to
    ``<history_dir>/<name>.jsonl``."""
    os.makedirs(history_dir, exist_ok=True)
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))
        ).stdout.strip() or None
    except Exception:
        commit = None
    rec = {"ts": round(time.time(), 3), "commit": commit,
           "rows": cur.get("rows", [])}
    with open(os.path.join(history_dir, f"{name}.jsonl"), "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--dir", required=True,
                   help="directory of freshly generated <name>.json files")
    p.add_argument("--baselines", default=DEFAULT_BASELINES,
                   help="directory of committed BENCH_<name>.json baselines")
    p.add_argument("--rel", type=float, default=0.35,
                   help="relative tolerance on numeric fields")
    p.add_argument("--abs", dest="abs_tol", type=float, default=2.0,
                   help="absolute slack (keeps small counts from tripping "
                        "the relative band)")
    p.add_argument("--update", action="store_true",
                   help="re-seed the baselines from --dir instead of "
                        "comparing (commit the diff deliberately)")
    p.add_argument("--history", default=None, metavar="DIR",
                   help="cross-commit history: append each run's rows to "
                        "DIR/<name>.jsonl and ALSO gate numeric fields "
                        "against the rolling median of the last "
                        "--history-n recorded runs")
    p.add_argument("--history-n", type=int, default=8,
                   help="rolling window: gate against the median of the "
                        "last N history records")
    p.add_argument("--history-min", type=int, default=3,
                   help="arm the history gate only once this many records "
                        "exist (fresh benchmarks pass while their trail "
                        "accumulates)")
    args = p.parse_args(argv)

    if args.update:
        os.makedirs(args.baselines, exist_ok=True)
        for fn in sorted(os.listdir(args.dir)):
            if not fn.endswith(".json"):
                continue
            dst = os.path.join(args.baselines, f"BENCH_{fn[:-5]}.json")
            shutil.copyfile(os.path.join(args.dir, fn), dst)
            print(f"[check_bench] seeded {dst}")
        return 0

    if not os.path.isdir(args.baselines):
        print(f"[check_bench] no baselines at {args.baselines}; run with "
              f"--update to seed them")
        return 1
    problems = []
    checked = 0
    for fn in sorted(os.listdir(args.baselines)):
        m = re.fullmatch(r"BENCH_(.+)\.json", fn)
        if not m:
            continue
        name = m.group(1)
        cur_path = os.path.join(args.dir, f"{name}.json")
        if not os.path.exists(cur_path):
            problems.append(f"{name}: baseline exists but {cur_path} was "
                            f"not generated this run")
            continue
        with open(os.path.join(args.baselines, fn)) as f:
            base = json.load(f)
        with open(cur_path) as f:
            cur = json.load(f)
        problems += compare_rows(name, base.get("rows", []),
                                 cur.get("rows", []),
                                 rel=args.rel, abs_tol=args.abs_tol)
        if args.history is not None:
            records = _load_history(
                os.path.join(args.history, f"{name}.jsonl"), args.history_n)
            problems += compare_history(
                name, records, cur.get("rows", []), rel=args.rel,
                abs_tol=args.abs_tol, min_runs=args.history_min)
            append_history(args.history, name, cur)
        checked += 1
    for pr in problems:
        print(f"[check_bench] DRIFT {pr}")
    print(f"[check_bench] {checked} benchmarks checked, "
          f"{len(problems)} problems")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
