"""Logical-axis sharding rules (MaxText-style), resolved per mesh/shape-kind.

Every ParamSpec / activation names its dims with *logical* axes; rules map a
logical axis to mesh axis name(s). Conflicting duplicate mesh axes within one
PartitionSpec resolve first-wins -> None (documented behaviour: e.g. with
``experts -> model`` the per-expert ``mlp`` dim falls back to replicated).

A context manager installs the active (mesh, rules) so model code can write
``logical_constraint(x, ("act_batch", "act_seq", "act_embed"))`` without
threading mesh state everywhere; outside a context it is the identity.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[None, str, Tuple[str, ...]]

# ---------------------------------------------------------------------------
# Rule tables
# ---------------------------------------------------------------------------

# Weight logical axes:
#   embed      : d_model dim of weights           -> FSDP over data
#   heads      : q-heads*head_dim dim             -> TP over model
#   kv_heads   : kv-heads*head_dim dim            -> TP over model
#   mlp        : FFN hidden dim                   -> TP over model
#   vocab      : embedding/vocab dim              -> TP over model
#   experts    : expert dim of MoE weights        -> EP over model (or None for TP)
#   expert_mlp : per-expert FFN hidden            -> TP over model in TP/C4 mode
#   layers     : stacked-scan dim                 -> never sharded
#   conv/state : ssm small dims                   -> never sharded
# Activation logical axes:
#   act_batch, act_seq, act_embed, act_heads, act_kv_seq, act_vocab, act_exp

def base_rules(*, multi_pod: bool, shape_kind: str,
               moe_sharding: str = "tp") -> Dict[str, MeshAxes]:
    """The paper-faithful layout: TP within a pod (incl. experts, C4), DP/FSDP
    over data, EP across pods when multi-pod and moe_sharding='auto'."""
    data: MeshAxes = "data"
    batch: MeshAxes = ("pod", "data") if multi_pod else "data"
    rules: Dict[str, MeshAxes] = {
        "embed": data,           # FSDP
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "layers": None,
        "conv": None,
        "state": None,
        "act_batch": batch,
        "act_seq": None,
        "act_embed": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_kv_seq": None,
        "act_vocab": "model",
        "act_mlp": "model",
        # MoE dispatch-buffer capacity dim (tokens-per-expert): shard over
        # data — the (E, C, d) slot buffers scale with the global token count
        # and must not replicate across the data axis.
        "act_cap": "data",
    }
    if moe_sharding == "ep":
        rules.update(experts="model", expert_mlp=None, act_exp="model")
    elif moe_sharding == "tp":  # paper C4: every device sees all experts
        rules.update(experts=None, expert_mlp="model", act_exp=None)
    else:  # auto: EP across pods, TP within (paper's multi-node layout)
        if multi_pod:
            rules.update(experts="pod", expert_mlp="model", act_exp="pod")
            rules["act_batch"] = "data"  # pod axis is consumed by experts
        else:
            rules.update(experts=None, expert_mlp="model", act_exp=None)
    if shape_kind == "decode":
        # long-context decode: shard the KV sequence (context parallelism);
        # batch=1 cells cannot use the data axis for batch anyway.
        rules["act_kv_seq"] = batch if shape_kind == "decode" else None
        rules["act_batch"] = None
    return rules


def decode_rules_batched(*, multi_pod: bool,
                         moe_sharding: str = "tp") -> Dict[str, MeshAxes]:
    """decode_32k: batch is large (128) -> shard batch over data, replicate KV seq."""
    rules = base_rules(multi_pod=multi_pod, shape_kind="train",
                       moe_sharding=moe_sharding)
    rules["act_kv_seq"] = None
    return rules


def rules_for(shape_kind: str, global_batch: int, *, multi_pod: bool,
              moe_sharding: str = "tp") -> Dict[str, MeshAxes]:
    if shape_kind == "decode" and global_batch > 1:
        return decode_rules_batched(multi_pod=multi_pod, moe_sharding=moe_sharding)
    return base_rules(multi_pod=multi_pod, shape_kind=shape_kind,
                      moe_sharding=moe_sharding)


# ---------------------------------------------------------------------------
# Resolution
# ---------------------------------------------------------------------------

def resolve_pspec(axes: Sequence[Optional[str]],
                  rules: Dict[str, MeshAxes]) -> P:
    """Map logical axes -> PartitionSpec, dropping duplicate mesh axes
    (first occurrence wins)."""
    used: set = set()
    parts = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            parts.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        keep = tuple(a for a in ms if a not in used)
        used.update(keep)
        if not keep:
            parts.append(None)
        elif len(keep) == 1:
            parts.append(keep[0])
        else:
            parts.append(keep)
    return P(*parts)


def fit_pspec_to_shape(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop mesh axes that do not evenly divide their dim (e.g. 12 KV heads on
    a 16-way model axis, vocab 50280 on 16): keep the largest dividing prefix
    of each dim's axis tuple. Keeps every lowering legal without per-arch
    special cases; the dropped axis falls back to replication for that dim."""
    parts = []
    for dim, part in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if part is None:
            parts.append(None)
            continue
        ms = (part,) if isinstance(part, str) else tuple(part)
        keep = []
        prod = 1
        for a in ms:
            n = mesh.shape[a]
            if dim % (prod * n) == 0:
                keep.append(a)
                prod *= n
            else:
                break
        if not keep:
            parts.append(None)
        elif len(keep) == 1:
            parts.append(keep[0])
        else:
            parts.append(tuple(keep))
    return P(*parts)


class ShardingContext:
    def __init__(self, mesh: Optional[Mesh], rules: Dict[str, MeshAxes]):
        self.mesh = mesh
        self.rules = dict(rules)

    def sharding(self, axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        assert self.mesh is not None
        spec = resolve_pspec(axes, self.rules)
        if shape is not None:
            spec = fit_pspec_to_shape(spec, shape, self.mesh)
        return NamedSharding(self.mesh, spec)

    def tree_shardings(self, axes_tree, shape_tree=None):
        is_axes = lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x)
        if shape_tree is None:
            return jax.tree_util.tree_map(
                lambda a: self.sharding(a), axes_tree, is_leaf=is_axes)
        return jax.tree_util.tree_map(
            lambda a, s: self.sharding(a, s.shape), axes_tree, shape_tree,
            is_leaf=is_axes)


_CTX: contextvars.ContextVar = contextvars.ContextVar("sharding_ctx", default=None)


@contextlib.contextmanager
def sharding_context(mesh: Optional[Mesh], rules: Dict[str, MeshAxes]):
    ctx = ShardingContext(mesh, rules)
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def current_context() -> Optional[ShardingContext]:
    return _CTX.get()


def logical_constraint(x, axes: Sequence[Optional[str]]):
    """Apply with_sharding_constraint if a sharding context is active."""
    ctx = _CTX.get()
    if ctx is None or ctx.mesh is None:
        return x
    spec = resolve_pspec(axes, ctx.rules)
    spec = fit_pspec_to_shape(spec, x.shape, ctx.mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))
