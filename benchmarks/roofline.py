"""§Roofline aggregation: read artifacts/dryrun/*.json into the per-cell
roofline table (markdown + CSV on stdout).

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir artifacts/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List


def load(dirname: str) -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "mesh": "-", "status": "skipped"})
            continue
        r = rec.get("roofline", {})
        mem = rec.get("memory_analysis", {})
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "status": rec.get("status"),
            "t_compute": r.get("t_compute"), "t_memory": r.get("t_memory"),
            "t_collective": r.get("t_collective"),
            "dominant": r.get("dominant"), "t_bound": r.get("t_bound"),
            "t_ideal": r.get("t_ideal"),
            "roofline_frac": r.get("roofline_fraction"),
            "useful_flop_ratio": r.get("useful_flop_ratio"),
            "model_tflops": (r.get("model_flops_global", 0) or 0) / 1e12,
            "hlo_tflops": (r.get("flops_global", 0) or 0) / 1e12,
            "temp_gb_per_dev": (mem.get("temp_size_in_bytes", 0) or 0) / 1e9,
            "moe_impl": rec.get("moe_impl"),
        })
    return rows


def markdown_table(rows: List[Dict]) -> str:
    cols = ["arch", "shape", "mesh", "dominant", "t_compute", "t_memory",
            "t_collective", "t_bound", "t_ideal", "roofline_frac",
            "useful_flop_ratio", "temp_gb_per_dev"]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "---|" * len(cols)]
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | skipped "
                       "(full attention) |" + " |" * (len(cols) - 4))
            continue
        vals = []
        for c in cols:
            v = r.get(c)
            vals.append(f"{v:.4g}" if isinstance(v, float) else str(v))
        out.append("| " + " | ".join(vals) + " |")
    return "\n".join(out)


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default="artifacts/dryrun")
    p.add_argument("--format", default="md", choices=["md", "csv"])
    args = p.parse_args()
    rows = load(args.dir)
    if args.format == "md":
        print(markdown_table(rows))
    else:
        from benchmarks.common import print_rows
        print_rows("roofline", rows)


if __name__ == "__main__":
    main()
