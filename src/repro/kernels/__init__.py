"""Pallas TPU kernels for the paper's two execution paths + jnp oracles.

compute path (xPU analogue):    flash_attn.py, moe_gemm.py
bandwidth path (Logic-PIM):     decode_attn.py (dense + paged), moe_gemv.py
wrappers / oracles:             ops.py, ref.py
"""
from jax.experimental.pallas import tpu as _pltpu

# --- JAX version compat -----------------------------------------------------
# The TPU compiler-params dataclass was renamed across JAX releases
# (TPUCompilerParams <-> CompilerParams). Every kernel module builds its
# compiler params through this shim so either spelling of JAX works.
_COMPILER_PARAMS_CLS = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Construct pltpu compiler params under whichever name this JAX has."""
    return _COMPILER_PARAMS_CLS(**kwargs)


from repro.kernels.ops import (decode_attention, flash_attention, moe_gemm,
                               moe_gemv, paged_decode_attention,
                               ragged_moe_gemm)

__all__ = ["decode_attention", "flash_attention", "moe_gemm", "moe_gemv",
           "paged_decode_attention", "ragged_moe_gemm",
           "tpu_compiler_params"]
