"""Benchmark-suite smoke: every figure module runs in quick mode and
produces sane rows (guards the full paper-reproduction harness)."""
import importlib

import pytest

FIGS = ["fig04_opb_breakdown", "fig05_hetero", "fig08_edap", "fig10_flows",
        "fig11_throughput", "fig12_latency", "fig14_bankpim", "fig15_energy",
        "fig16_split", "skew_study"]


@pytest.mark.parametrize("name", FIGS)
def test_benchmark_quick(name):
    mod = importlib.import_module(f"benchmarks.{name}")
    rows = mod.run(quick=True)
    assert rows, name
    assert all(isinstance(r, dict) for r in rows)


def test_key_claims_hold():
    """The quick benchmarks must show the paper's directions."""
    import benchmarks.fig11_throughput as f11
    rows = f11.run(quick=True)
    by = {(r["system"], r["policy"], r["l_in"]): r["speedup_vs_gpu"]
          for r in rows}
    assert by[("duplex", "duplex", 256)] > 1.3
    assert by[("duplex_et", "duplex_pe_et", 256)] >= by[("duplex", "duplex",
                                                         256)] * 0.95

    import benchmarks.fig10_flows as f10
    rows = f10.run(quick=True)
    dec = {r["flow"]: r["time_vs_serial"] for r in rows
           if r["stage"] == "decode_b64_ctx2k"}
    assert dec["minibatch_split"] > 1.0 > dec["duplex_pe"]
