from repro.serving.engine import ServingEngine, StageReport
from repro.serving.kvmanager import KVManager
from repro.serving.request import Request, RequestState
from repro.serving.sampling import SamplingParams, sample
from repro.serving.scheduler import ContinuousBatchingScheduler, StageDecision

__all__ = ["ServingEngine", "StageReport", "KVManager", "Request",
           "RequestState", "SamplingParams", "sample",
           "ContinuousBatchingScheduler", "StageDecision"]
