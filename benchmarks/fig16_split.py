"""Fig. 16 / §VIII-A: phase-split (Splitwise-style) vs non-split Duplex.

Reproduces: the split system (2 prefill + 2 decode Duplex devices) gets good
tail TBT (no mixed stages in the decode pool) but loses throughput — weight
duplication wastes KV capacity and each phase only uses half the devices.
"""
from __future__ import annotations

from typing import Dict, List

from repro.sim.engine_sim import simulate, simulate_split
from repro.sim.metrics import latency_summary
from repro.sim.paper_models import MIXTRAL
from repro.sim.specs import duplex_system
from repro.sim.workload import gaussian_requests

from benchmarks.common import fresh


def run(quick: bool = True) -> List[Dict]:
    cfg = MIXTRAL
    rows = []
    cases = [(256, 128)] if quick else [(256, 256), (1024, 1024),
                                        (4096, 4096)]
    for l_in, l_out in cases:
        proto = gaussian_requests(48 if quick else 160, l_in, l_out, seed=16)
        reqs_ns = fresh(proto)
        ns = simulate(duplex_system(1, 4), cfg, "duplex_pe", reqs_ns,
                      max_batch=128)
        lat_ns = latency_summary(reqs_ns)
        reqs_sp = fresh(proto)
        sp = simulate_split(duplex_system(1, 2, name="split_prefill"),
                            duplex_system(1, 2, name="split_decode"),
                            cfg, "duplex_pe", reqs_sp)
        lat_sp = latency_summary(reqs_sp)
        rows.append({
            "l_in": l_in, "l_out": l_out,
            "nonsplit_tok_s": ns.throughput, "split_tok_s": sp.throughput,
            "split_over_nonsplit_thr": sp.throughput / ns.throughput,
            "split_tbt_p99_ratio": lat_sp["tbt_p99"] / lat_ns["tbt_p99"],
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows("fig16_split", run(quick=False))
