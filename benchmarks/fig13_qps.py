"""Fig. 13: TBT / T2FT / E2E vs queries-per-second (Poisson arrivals) for
Mixtral, (L_in, L_out) = (4096, 512), max batch 128.

Reproduces: Duplex always beats GPU; GPU saturates (T2FT skyrockets) around
9 QPS while Duplex sustains ~14, near 2xGPU.
"""
from __future__ import annotations

from typing import Dict, List

from repro.sim.engine_sim import simulate
from repro.sim.metrics import latency_summary
from repro.sim.paper_models import MIXTRAL
from repro.sim.specs import default_system
from repro.sim.workload import gaussian_requests, poisson_arrivals

from benchmarks.common import fresh

VARIANTS = [("gpu", "gpu"), ("gpu2x", "gpu"), ("duplex_et", "duplex_pe_et")]


def run(quick: bool = True) -> List[Dict]:
    cfg = MIXTRAL
    rows = []
    l_in, l_out = (4096, 512) if not quick else (1024, 64)
    qps_list = (4, 8) if quick else (4, 6, 8, 10, 12, 14, 16)
    n_req = 32 if quick else 160
    for qps in qps_list:
        proto = poisson_arrivals(
            gaussian_requests(n_req, l_in, l_out, seed=13), qps, seed=13)
        for kind, policy in VARIANTS:
            reqs = fresh(proto)
            simulate(default_system(cfg, kind), cfg, policy, reqs,
                     max_batch=128, max_prefill_per_stage=2)
            lat = latency_summary(reqs)
            rows.append({
                "qps": qps, "system": kind, "policy": policy,
                "tbt_p50": lat["tbt_p50"], "tbt_p90": lat["tbt_p90"],
                "t2ft_p50": lat["t2ft_p50"], "e2e_p50": lat["e2e_p50"],
            })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows("fig13_qps", run(quick=False))
