"""int8 KV pages (ROADMAP "DESIGN: int8 KV pages"): paged int8 kernels vs
oracle, dense-int8 vs paged-int8 engine parity (decode-only AND chunked
mixed stages), capacity doubling at a fixed pool byte budget, and the
decode_int8 benchmark acceptance metrics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.models.attention import (chunk_attention, paged_gather_kv,
                                    paged_gather_scale, quantize_kv)
from repro.serving.engine import ServingEngine
from repro.serving.kvmanager import (KVManager, kv_token_bytes,
                                     pages_for_budget)
from repro.serving.request import Request


# ---------------------------------------------------------------------------
# paged int8 decode kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------

def _int8_paged_case(seed, B, KV, qpk, hd, page, maxp):
    """Random int8 page pools with per-(token, kv-head) scale pools and
    shuffled (non-contiguous) block tables."""
    rng = np.random.default_rng(seed)
    P = 1 + B * maxp
    q = jnp.asarray(rng.standard_normal((B, 1, KV * qpk, hd)), jnp.float32)
    k8, ks = quantize_kv(jnp.asarray(
        rng.standard_normal((P, KV, page, hd)), jnp.float32))
    v8, vs = quantize_kv(jnp.asarray(
        rng.standard_normal((P, KV, page, hd)), jnp.float32))
    lengths = rng.integers(1, maxp * page + 1, size=B)
    bt = np.zeros((B, maxp), np.int32)
    free = list(range(1, P))
    rng.shuffle(free)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // page)):
            bt[b, j] = free.pop()
    return (q, k8, ks, v8, vs, jnp.asarray(lengths, jnp.int32),
            jnp.asarray(bt))


def _dense_view(pool, bt):
    B, maxp = bt.shape
    _, KV, page, hd = pool.shape
    return pool[bt].transpose(0, 2, 1, 3, 4).reshape(B, KV, maxp * page, hd)


def _dense_scale_view(pool, bt):
    B, maxp = bt.shape
    _, KV, page = pool.shape
    return pool[bt].transpose(0, 2, 1, 3).reshape(B, KV, maxp * page)


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (12, 0.0), (0, 8.0),
                                            (20, 5.0)])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_paged_int8_kernel_matches_ref(seed, window, softcap):
    """The in-kernel scaled-dot path must land within int8 quantization
    noise (q/pv requantize at 1/254 relative) of the dequantized oracle."""
    B, KV, qpk, hd, page, maxp = 3, 2, 4, 32, 16, 4
    q, k8, ks, v8, vs, lengths, bt = _int8_paged_case(seed, B, KV, qpk, hd,
                                                      page, maxp)
    out = ops.paged_decode_attention(q, k8, v8, lengths, bt, k_scales=ks,
                                     v_scales=vs, window=window,
                                     softcap=softcap, interpret=True)
    exp = ref.int8_decode_attention_ref(
        q.reshape(B, KV, qpk, hd), _dense_view(k8, bt),
        _dense_scale_view(ks, bt), _dense_view(v8, bt),
        _dense_scale_view(vs, bt), lengths, window=window, softcap=softcap)
    rel = float(jnp.abs(out.reshape(B, KV, qpk, hd) - exp).max()
                / jnp.abs(exp).max())
    assert rel < 0.03, rel


def test_paged_int8_kernel_pages_bound_trims_grid():
    B, KV, qpk, hd, page, maxp = 2, 1, 2, 16, 8, 8
    q, k8, ks, v8, vs, _, bt = _int8_paged_case(7, B, KV, qpk, hd, page,
                                                maxp)
    lengths = jnp.asarray([13, 20], jnp.int32)       # <= 3 live pages
    out = ops.paged_decode_attention(q, k8, v8, lengths, bt, k_scales=ks,
                                     v_scales=vs, pages_bound=3,
                                     interpret=True)
    exp = ref.int8_decode_attention_ref(
        q.reshape(B, KV, qpk, hd), _dense_view(k8, bt),
        _dense_scale_view(ks, bt), _dense_view(v8, bt),
        _dense_scale_view(vs, bt), lengths)
    rel = float(jnp.abs(out.reshape(B, KV, qpk, hd) - exp).max()
                / jnp.abs(exp).max())
    assert rel < 0.03, rel


@pytest.mark.parametrize("softcap", [0.0, 4.0])
def test_chunked_int8_kernel_matches_dequantized_chunk(softcap):
    """Chunked-prefill int8 kernel vs the fp chunk oracle run on the
    dequantized gathered context (prefix + in-flight chunk, causal mask)."""
    rng = np.random.default_rng(11)
    B, KV, qpk, hd, page, maxp, Sc = 2, 2, 2, 16, 8, 6, 8
    H = KV * qpk
    P = 1 + B * maxp
    q = jnp.asarray(rng.standard_normal((B, Sc, H, hd)), jnp.float32)
    k8, ks = quantize_kv(jnp.asarray(
        rng.standard_normal((P, KV, page, hd)), jnp.float32))
    v8, vs = quantize_kv(jnp.asarray(
        rng.standard_normal((P, KV, page, hd)), jnp.float32))
    starts = jnp.asarray([10, 0], jnp.int32)        # one mid-prompt chunk
    clens = jnp.asarray([Sc, 5], jnp.int32)         # one padded chunk row
    totals = starts + clens
    bt = np.zeros((B, maxp), np.int32)
    free = list(range(1, P))
    rng.shuffle(free)
    for b in range(B):
        for j in range(-(-int(totals[b]) // page)):
            bt[b, j] = free.pop()
    bt = jnp.asarray(bt)
    out = ops.chunked_prefill_attention(q, k8, v8, totals, starts, bt,
                                        k_scales=ks, v_scales=vs,
                                        softcap=softcap, interpret=True)
    kd = (paged_gather_kv(k8, bt).astype(jnp.float32)
          * paged_gather_scale(ks, bt)[..., None])
    vd = (paged_gather_kv(v8, bt).astype(jnp.float32)
          * paged_gather_scale(vs, bt)[..., None])
    positions = starts[:, None] + jnp.arange(Sc, dtype=jnp.int32)[None]
    kv_pos = jnp.broadcast_to(jnp.arange(maxp * page, dtype=jnp.int32)[None],
                              (B, maxp * page))
    exp = chunk_attention(q, kd, vd, positions, kv_pos, totals,
                          softcap=softcap)
    for b in range(B):              # compare live chunk rows only
        n = int(clens[b])
        rel = float(jnp.abs(out[b, :n] - exp[b, :n]).max()
                    / jnp.abs(exp[b, :n]).max())
        assert rel < 0.03, (b, rel)


# ---------------------------------------------------------------------------
# engine end-to-end: dense-int8 vs paged-int8 greedy parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_cfg():
    from repro.configs.base import small_test_config
    from repro.models.model import init_model
    cfg = small_test_config("paged-int8")
    params = init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _run_engine(cfg, params, layout, *, use_kernels=False, chunk=None,
                prompts=None):
    eng = ServingEngine(cfg, params, max_slots=4, max_len=64,
                        use_duplex=False, use_kernels=use_kernels,
                        kv_quant=True, kv_layout=layout, kv_page_size=8,
                        prefill_chunk_tokens=chunk)
    if prompts is None:
        prompts = [list(range(1, 4 + i % 5)) for i in range(7)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    return eng, {r.rid: tuple(r.output) for r in reqs}


def test_engine_paged_int8_matches_dense_int8_decode(engine_cfg):
    """Both layouts quantize the same K/V with the same per-token scales and
    run the same folded-scale dots — greedy tokens must agree (decode-only
    stages; XLA and kernel lowerings)."""
    cfg, params = engine_cfg
    _, dense_out = _run_engine(cfg, params, "dense")
    eng, paged_out = _run_engine(cfg, params, "paged")
    assert dense_out == paged_out
    assert eng.kv.live_pages == 0        # pages recycled on retire
    _, paged_k = _run_engine(cfg, params, "paged", use_kernels=True)
    assert dense_out == paged_k


def test_engine_paged_int8_matches_dense_int8_mixed_chunks(engine_cfg):
    """Mixed chunked stages (each prompt fits one chunk, riding alongside
    other requests' decode rows): the chunk write+attend int8 paths of both
    layouts quantize identical K/V — greedy tokens agree exactly."""
    cfg, params = engine_cfg
    prompts = [list(range(1, 10 + 2 * i)) for i in range(5)]   # 9..17 toks
    _, dense_out = _run_engine(cfg, params, "dense", chunk=24,
                               prompts=prompts)
    _, paged_out = _run_engine(cfg, params, "paged", chunk=24,
                               prompts=prompts)
    assert dense_out == paged_out
    _, paged_k = _run_engine(cfg, params, "paged", use_kernels=True,
                             chunk=24, prompts=prompts)
    assert dense_out == paged_k


def test_engine_paged_int8_multi_chunk_continuation(engine_cfg):
    """Prompts much longer than the chunk budget prefill across several
    stages through the int8 continuation paths. Bit-exact cross-layout
    parity is NOT guaranteed here: pv requantization happens over
    different gather widths (and per page on the kernel path), so a greedy
    sample sitting on a rounding boundary can flip — after which that
    request's suffix legitimately diverges. Require completion plus
    majority first-token agreement (first tokens depend only on prefill,
    no compounding)."""
    cfg, params = engine_cfg
    prompts = [list(range(1, 20 + 3 * i)) for i in range(5)]
    _, dense_out = _run_engine(cfg, params, "dense", chunk=8,
                               prompts=prompts)
    for kernels in (False, True):
        _, paged_out = _run_engine(cfg, params, "paged",
                                   use_kernels=kernels, chunk=8,
                                   prompts=prompts)
        first = [dense_out[r][0] == paged_out[r][0] for r in dense_out]
        assert sum(first) >= 3, (kernels, dense_out, paged_out)


def test_engine_int8_kv_bytes_accounting(engine_cfg):
    """StageReport.kv_bytes_streamed must reflect the actual cache bytes:
    int8 pages stream hd + 4 scale bytes per (token, kv-head) per K/V
    instead of hd * itemsize."""
    cfg, params = engine_cfg
    eng8, _ = _run_engine(cfg, params, "paged")
    engf = ServingEngine(cfg, params, max_slots=4, max_len=64,
                         use_duplex=False, kv_layout="paged",
                         kv_page_size=8)
    reqs = [Request(rid=i, prompt=list(range(1, 4 + i % 5)),
                    max_new_tokens=6) for i in range(7)]
    engf.run(reqs)
    ratio = kv_token_bytes(cfg) / kv_token_bytes(cfg, kv_quant=True)
    b8 = [r.kv_bytes_streamed for r in eng8.reports if r.num_decode]
    bf = [r.kv_bytes_streamed for r in engf.reports if r.num_decode]
    # identical request sets -> identical live pages per stage: the byte
    # ratio is exactly the per-token dtype ratio
    assert len(b8) == len(bf)
    np.testing.assert_allclose(np.asarray(bf) / np.asarray(b8), ratio)
    assert ratio >= 1.7


# ---------------------------------------------------------------------------
# capacity: a fixed HBM budget admits ~2x the pages at int8 (Fig. 5(c))
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hd64_cfg():
    """hd=64: the deployment-shaped head dim, where the fp32 scale overhead
    is 4/64 and fp16->int8 gives the ~2x ratio (at the tiny test hd=16 the
    overhead is 25% and the ratio is only 1.6x)."""
    from repro.configs.base import small_test_config
    return small_test_config("cap-hd64", d_model=128, num_heads=4,
                             num_kv_heads=2, head_dim=64)


def test_pages_for_budget_doubles_capacity(hd64_cfg):
    budget = 1 << 22
    p16 = pages_for_budget(hd64_cfg, 8, budget, dtype="bfloat16")
    p8 = pages_for_budget(hd64_cfg, 8, budget, kv_quant=True)
    assert 1.7 <= p8 / p16 <= 2.2, (p16, p8)


def test_kvmanager_int8_pool_halves_bytes(hd64_cfg):
    """Same page count -> the int8 pool occupies ~half the HBM of the bf16
    pool (scale bytes ride along); bytes_per_slot counts the scale pools
    automatically because it sums actual cache leaves."""
    kv16 = KVManager(hd64_cfg, max_slots=2, max_len=32, layout="paged",
                     page_size=8, dtype="bfloat16")
    kv8 = KVManager(hd64_cfg, max_slots=2, max_len=32, layout="paged",
                    page_size=8, kv_quant=True)
    ratio = kv16._total_bytes() / kv8._total_bytes()
    assert 1.7 <= ratio <= 2.2, ratio
    assert kv8.bytes_per_slot() * 1.7 <= kv16.bytes_per_slot()


def test_engine_int8_budgeted_pool_throttles_and_completes(engine_cfg):
    """An int8 pool sized from a byte budget admits more requests than the
    fp pool would, and admission backpressure still prevents exhaustion."""
    cfg, params = engine_cfg
    budget = 40 * 8 * kv_token_bytes(cfg, kv_quant=True) * cfg.num_layers
    pages = pages_for_budget(cfg, 8, budget, kv_quant=True)
    eng = ServingEngine(cfg, params, max_slots=4, max_len=32,
                        use_duplex=False, kv_quant=True, kv_layout="paged",
                        kv_page_size=8, kv_num_pages=1 + pages)
    reqs = [Request(rid=i, prompt=list(range(1, 10)), max_new_tokens=8)
            for i in range(6)]
    eng.run(reqs)
    assert all(r.done for r in reqs)
    assert eng.kv.live_pages == 0


# ---------------------------------------------------------------------------
# benchmark smoke (the acceptance metrics)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_decode_int8_benchmark_acceptance():
    import benchmarks.decode_int8 as bench
    rows = bench.run(quick=True)
    for r in rows:
        # >= 1.7x streamed-KV-byte reduction vs fp16 paged, equal occupancy
        assert r["reduction_paged_x"] >= 1.7, r
        # greedy tokens match the dense-int8 reference exactly
        assert r["int8_parity"], r
        # ~2x token capacity at equal pool bytes
        assert 1.7 <= r["capacity_x"] <= 2.2, r
