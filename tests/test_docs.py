"""Front-door health: README/docs links, flag matrix, quickstart snippet.

Mirrors the CI docs job (tools/check_docs.py) so `pytest -m "not slow"`
catches doc rot locally before CI does."""
import importlib.util
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _load_check_docs():
    spec = importlib.util.spec_from_file_location(
        "check_docs", ROOT / "tools" / "check_docs.py")
    mod = importlib.util.module_from_spec(spec)
    sys.modules.setdefault("check_docs", mod)
    spec.loader.exec_module(mod)
    return mod


def test_front_door_exists():
    assert (ROOT / "README.md").is_file()
    assert (ROOT / "docs" / "architecture.md").is_file()
    # ROADMAP keeps the north star + open items and links to the docs
    roadmap = (ROOT / "ROADMAP.md").read_text()
    assert "docs/architecture.md" in roadmap
    assert "Open items" in roadmap


def test_doc_links_resolve():
    assert _load_check_docs().check_links() == []


def test_readme_flags_match_serve_cli():
    assert _load_check_docs().check_flags() == []


def test_architecture_names_real_modules():
    """No module path named in the architecture doc may be absent from the
    tree (the acceptance criterion for the docs)."""
    import re
    text = (ROOT / "docs" / "architecture.md").read_text()
    for ref in re.findall(r"`([a-z_]+(?:/[a-z_0-9]+)+\.py)`", text):
        candidates = [ROOT / ref, ROOT / "src" / "repro" / ref,
                      ROOT / "src" / ref]
        assert any(c.is_file() for c in candidates), ref


def test_readme_quickstart_runs():
    """The README quickstart snippet performs an import + one engine step;
    executing it here means the front door cannot silently rot."""
    assert _load_check_docs().check_quickstart() == []
