"""Decoder-block composition per LayerKind + scan-able segment stacking."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, ATTN_BIDIR, ATTN_CROSS, ATTN_LOCAL,
                                DENSE, MAMBA, MOE, NONE, LayerKind, ModelConfig,
                                Segment)
from repro.models import attention as attn_mod
from repro.models.attention import (AttnCall, attention_decode_step,
                                    attention_forward, attention_specs,
                                    cross_attention_forward, cross_kv)
from repro.models.ffn import ffn_apply, ffn_specs
from repro.models.layers import rmsnorm, rmsnorm_specs
from repro.core.execution import moe_execute
from repro.models.moe import moe_specs
from repro.models.param import stack_specs
from repro.models.ssm import (mamba_decode_step, mamba_forward,
                              mamba_init_cache, mamba_specs)


def block_specs(cfg: ModelConfig, kind: LayerKind) -> dict:
    d = cfg.d_model
    pdtype = cfg.param_dtype
    specs: Dict[str, Any] = {"norm1": rmsnorm_specs(d, pdtype)}
    if kind.mixer == MAMBA:
        specs["mixer"] = mamba_specs(cfg)
    else:
        specs["mixer"] = attention_specs(cfg)
    if kind.mixer == ATTN_CROSS:
        specs["cross"] = attention_specs(cfg)
        specs["norm_cross"] = rmsnorm_specs(d, pdtype)
    if kind.ffn != NONE and not cfg.parallel_block:
        specs["norm2"] = rmsnorm_specs(d, pdtype)
    if kind.ffn == DENSE:
        specs["ffn"] = ffn_specs(cfg)
    elif kind.ffn == MOE:
        specs["ffn"] = moe_specs(cfg)
    return specs


def _attn_call(cfg: ModelConfig, kind: LayerKind) -> AttnCall:
    from repro.core.execution import current_plan
    plan = current_plan()
    kw = dict(q_block=plan.attn_q_block, kv_block=plan.attn_kv_block,
              score_bf16=plan.attn_score_bf16)
    if kind.mixer == ATTN_LOCAL:
        return AttnCall(causal=True, window=cfg.sliding_window, **kw)
    if kind.mixer == ATTN_BIDIR:
        return AttnCall(causal=False, **kw)
    return AttnCall(causal=True, **kw)


def block_forward(params, cfg: ModelConfig, kind: LayerKind, x, positions,
                  *, segment_ids=None, enc_out=None,
                  enc_segment_ids=None):
    """Train/prefill path. Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    if kind.mixer == MAMBA:
        mixer_out = mamba_forward(params["mixer"], cfg, h)
    else:
        mixer_out = attention_forward(params["mixer"], cfg, h, positions,
                                      _attn_call(cfg, kind),
                                      segment_ids=segment_ids)
    if cfg.parallel_block and kind.ffn != NONE:
        # command-r style: attn and ffn share the pre-norm input
        if kind.ffn == MOE:
            ffn_out, aux = moe_execute(params["ffn"], cfg, h)
        else:
            ffn_out = ffn_apply(params["ffn"], h)
        return x + mixer_out + ffn_out, aux
    x = x + mixer_out
    if kind.mixer == ATTN_CROSS:
        h = rmsnorm(params["norm_cross"], x, cfg.norm_eps)
        kv = cross_kv(params["cross"], cfg, enc_out)
        x = x + cross_attention_forward(params["cross"], cfg, h, kv,
                                        segment_ids=segment_ids,
                                        kv_segment_ids=enc_segment_ids)
    if kind.ffn == NONE:
        return x, aux
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if kind.ffn == MOE:
        ffn_out, aux = moe_execute(params["ffn"], cfg, h)
    else:
        ffn_out = ffn_apply(params["ffn"], h)
    return x + ffn_out, aux


# ---------------------------------------------------------------------------
# Decode path
# ---------------------------------------------------------------------------

def block_init_cache(cfg: ModelConfig, kind: LayerKind, batch: int,
                     max_len: int, dtype, kv_quant: bool = False, *,
                     paged: bool = False, page_size: int = 64,
                     num_pages: int = 0) -> dict:
    if paged:
        # Paged layout: this layer's share of the KV page pool. No per-slot
        # leaves — slot metadata (lengths, block tables) lives in the
        # KVManager and reaches decode as `attn_ctx`. Page 0 is the reserved
        # null page (write target of padded batch rows). ATTN_LOCAL stays
        # dense: its prefill cache is a ring buffer whose slots don't map
        # positionally onto pages (the paged kernel itself supports window
        # masking for standalone use).
        if kind.mixer != ATTN:
            raise ValueError(
                f"paged KV cache supports full self-attention decoder "
                f"layers only, got mixer={kind.mixer}")
        kv = cfg.num_kv_heads
        hd = cfg.resolved_head_dim
        if kv_quant:
            # int8 value pools + fp32 per-(token, kv-head) scale pools
            # addressed by the SAME block tables (ROADMAP "DESIGN: int8 KV
            # pages"): per-token bytes drop from 2·hd·itemsize to
            # 2·(hd + 4) — the scale rider streams with its page.
            return {
                "k_pages": jnp.zeros((num_pages, kv, page_size, hd),
                                     jnp.int8),
                "v_pages": jnp.zeros((num_pages, kv, page_size, hd),
                                     jnp.int8),
                "k_scale_pages": jnp.zeros((num_pages, kv, page_size),
                                           jnp.float32),
                "v_scale_pages": jnp.zeros((num_pages, kv, page_size),
                                           jnp.float32),
            }
        return {"k_pages": jnp.zeros((num_pages, kv, page_size, hd), dtype),
                "v_pages": jnp.zeros((num_pages, kv, page_size, hd), dtype)}
    if kind.mixer == MAMBA:
        return {"mamba": mamba_init_cache(cfg, batch, dtype)}
    window = cfg.sliding_window if kind.mixer == ATTN_LOCAL else 0
    # ring buffer (window + 1 dump slot) for local layers — bounds long-context
    # KV memory at O(window) instead of O(seq_len)
    size = min(max_len, window) + 1 if window > 0 else max_len
    kv = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    kv_dtype = jnp.int8 if kv_quant else dtype
    cache = {
        "k": jnp.zeros((batch, size, kv, hd), kv_dtype),
        "v": jnp.zeros((batch, size, kv, hd), kv_dtype),
        "pos": jnp.full((batch, size), jnp.iinfo(jnp.int32).max, jnp.int32),
        "len": jnp.zeros((batch,), jnp.int32),
    }
    if kv_quant:
        cache["k_scale"] = jnp.zeros((batch, size, kv), jnp.float32)
        cache["v_scale"] = jnp.zeros((batch, size, kv), jnp.float32)
    if kind.mixer == ATTN_CROSS:
        # cross-attention KV stays full-precision (written once per request)
        cache["cross_k"] = jnp.zeros((batch, max_len, kv, hd), dtype)
        cache["cross_v"] = jnp.zeros((batch, max_len, kv, hd), dtype)
        cache["cross_len"] = jnp.zeros((batch,), jnp.int32)
    return cache


def block_decode_step(params, cfg: ModelConfig, kind: LayerKind, x, cache,
                      attn_ctx=None, collect_counts: bool = False):
    """Single-token decode. Returns (x, new_cache, moe_counts). ``attn_ctx``
    carries the stage's slot metadata ({"lengths", "block_tables"} for paged
    caches; optional "valid" (B,) live-row mask excluding padded/dead rows
    from MoE routing counts and capacity). ``moe_counts`` is the layer's
    per-expert routed-token counts ((E,) fp32) when ``collect_counts`` and
    the block has an MoE ffn, else None — the serving engine feeds the
    actual counts (not a synthetic draw) back to the Duplex planner."""
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    valid = attn_ctx.get("valid") if attn_ctx else None
    counts = None

    def _ffn(h_in):
        nonlocal counts
        if kind.ffn != MOE:
            return ffn_apply(params["ffn"], h_in)
        out, stats = moe_execute(params["ffn"], cfg, h_in, return_stats=True,
                                 token_valid=valid)
        if collect_counts:
            counts = stats.counts.astype(jnp.float32)
        return out

    if kind.mixer == MAMBA:
        mixer_out, new_mamba = mamba_decode_step(params["mixer"], cfg, h,
                                                 cache["mamba"])
        new_cache = {"mamba": new_mamba}
    elif "k_pages" in cache:
        from repro.models.attention import paged_attention_decode_step
        window = cfg.sliding_window if kind.mixer == ATTN_LOCAL else 0
        mixer_out, new_cache = paged_attention_decode_step(
            params["mixer"], cfg, h, cache, attn_ctx, window=window)
    else:
        window = cfg.sliding_window if kind.mixer == ATTN_LOCAL else 0
        mixer_out, new_attn = attention_decode_step(params["mixer"], cfg, h,
                                                    cache, window=window)
        new_cache = dict(cache)
        new_cache.update(new_attn)
    if cfg.parallel_block and kind.ffn != NONE:
        ffn_out = _ffn(h)
        return x + mixer_out + ffn_out, new_cache, counts
    x = x + mixer_out
    if kind.mixer == ATTN_CROSS:
        h = rmsnorm(params["norm_cross"], x, cfg.norm_eps)
        from repro.models.attention import decode_attention
        B = x.shape[0]
        hd = cfg.resolved_head_dim
        q = jnp.einsum("bsd,dh->bsh", h, params["cross"]["wq"]["kernel"])
        q = q.reshape(B, 1, cfg.num_heads, hd)
        if cfg.qk_norm:
            q = rmsnorm(params["cross"]["q_norm"], q, cfg.norm_eps)
        out = decode_attention(q, cache["cross_k"], cache["cross_v"],
                               cache["cross_len"])
        x = x + jnp.einsum("bsh,hd->bsd", out.reshape(B, 1, -1),
                           params["cross"]["wo"]["kernel"])
    if kind.ffn == NONE:
        return x, new_cache, counts
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    ffn_out = _ffn(h)
    return x + ffn_out, new_cache, counts


def block_prefill(params, cfg: ModelConfig, kind: LayerKind, x, positions,
                  true_len, cache, *, segment_ids=None, enc_out=None):
    """Prefill path: like block_forward but also populates the decode cache.
    x: (B, S, d); true_len: (B,) valid prompt lengths. Returns (x, new_cache)."""
    from repro.models.attention import (write_prefill_cache, _project_qkv)
    h = rmsnorm(params["norm1"], x, cfg.norm_eps)
    new_cache = dict(cache)
    if kind.mixer == MAMBA:
        mixer_out, mcache = mamba_forward(params["mixer"], cfg, h,
                                          return_state=True)
        new_cache = {"mamba": mcache}
    else:
        window = cfg.sliding_window if kind.mixer == ATTN_LOCAL else 0
        call = _attn_call(cfg, kind)
        mixer_out, (k, v) = attention_forward(params["mixer"], cfg, h,
                                              positions, call,
                                              segment_ids=segment_ids,
                                              return_kv=True)
        new_cache.update(write_prefill_cache(cache, k, v, true_len,
                                             window=window))
    if cfg.parallel_block and kind.ffn != NONE:
        # must match block_forward exactly: attn and ffn share pre-norm input
        if kind.ffn == MOE:
            ffn_out, _ = moe_execute(params["ffn"], cfg, h)
        else:
            ffn_out = ffn_apply(params["ffn"], h)
        return x + mixer_out + ffn_out, new_cache
    x = x + mixer_out
    if kind.mixer == ATTN_CROSS:
        h = rmsnorm(params["norm_cross"], x, cfg.norm_eps)
        ck, cv = cross_kv(params["cross"], cfg, enc_out)
        x = x + cross_attention_forward(params["cross"], cfg, h, (ck, cv),
                                        segment_ids=segment_ids)
        new_cache["cross_k"] = ck.astype(cache["cross_k"].dtype)
        new_cache["cross_v"] = cv.astype(cache["cross_v"].dtype)
        new_cache["cross_len"] = jnp.full_like(true_len, ck.shape[1])
    if kind.ffn == NONE:
        return x, new_cache
    h = rmsnorm(params["norm2"], x, cfg.norm_eps)
    if kind.ffn == MOE:
        ffn_out, _ = moe_execute(params["ffn"], cfg, h)
    else:
        ffn_out = ffn_apply(params["ffn"], h)
    return x + ffn_out, new_cache


# ---------------------------------------------------------------------------
# Segments (scan over stacked super-blocks)
# ---------------------------------------------------------------------------

def segment_specs(cfg: ModelConfig, seg: Segment) -> dict:
    one = {"blocks": tuple(block_specs(cfg, k) for k in seg.pattern)}
    return stack_specs(one, seg.repeats)


def segment_forward(params, cfg: ModelConfig, seg: Segment, x, positions, *,
                    segment_ids=None, enc_out=None, enc_segment_ids=None,
                    remat: str = "full"):
    """scan over the segment's stacked super-blocks; returns (x, aux_sum)."""

    def super_block(x, blk_params):
        aux_total = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(seg.pattern):
            x, aux = block_forward(blk_params["blocks"][i], cfg, kind, x,
                                   positions, segment_ids=segment_ids,
                                   enc_out=enc_out,
                                   enc_segment_ids=enc_segment_ids)
            aux_total = aux_total + aux
        return x, aux_total

    if remat == "full":
        super_block = jax.checkpoint(super_block,
                                     policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        super_block = jax.checkpoint(
            super_block,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def body(x, blk_params):
        return super_block(x, blk_params)

    x, auxs = jax.lax.scan(body, x, params)
    return x, auxs.sum()


def segment_init_cache(cfg: ModelConfig, seg: Segment, batch: int,
                       max_len: int, dtype, kv_quant: bool = False, *,
                       paged: bool = False, page_size: int = 64,
                       num_pages: int = 0):
    one = {"blocks": tuple(block_init_cache(cfg, k, batch, max_len, dtype,
                                            kv_quant, paged=paged,
                                            page_size=page_size,
                                            num_pages=num_pages)
                           for k in seg.pattern)}
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (seg.repeats,) + a.shape).copy(), one)


def segment_decode_step(params, cfg: ModelConfig, seg: Segment, x, cache,
                        attn_ctx=None, collect_counts: bool = False):
    """With ``collect_counts`` also returns the segment's summed per-expert
    MoE routing counts ((E,) fp32, zeros if the segment has no MoE)."""
    E = cfg.moe.num_experts if (collect_counts and cfg.moe) else 0

    def body(x, inp):
        blk_params, blk_cache = inp
        new_caches = []
        counts = jnp.zeros((E,), jnp.float32)
        for i, kind in enumerate(seg.pattern):
            x, nc, cnt = block_decode_step(blk_params["blocks"][i], cfg,
                                           kind, x, blk_cache["blocks"][i],
                                           attn_ctx=attn_ctx,
                                           collect_counts=collect_counts)
            new_caches.append(nc)
            if cnt is not None and E:
                counts = counts + cnt
        return x, ({"blocks": tuple(new_caches)}, counts)

    x, (new_cache, counts) = jax.lax.scan(body, x, (params, cache))
    if collect_counts:
        return x, new_cache, counts.sum(axis=0)
    return x, new_cache


# ---------------------------------------------------------------------------
# Unified mixed stage: decode rows + prefill-chunk rows in one token stream
# (ROADMAP "DESIGN: chunked prefill"). Attention runs per group (decode
# kernel vs chunked-prefill path against the same cache); norms/FFN/MoE run
# over the concatenated token stream, so the count-threaded ragged duplex
# MoE covers BOTH halves of the stage.
# ---------------------------------------------------------------------------

def block_mixed_step(params, cfg: ModelConfig, kind: LayerKind, xd, xc,
                     cache, attn_ctx, chunk_ctx,
                     collect_counts: bool = False):
    """One block of a unified mixed stage.

    xd: (Bd, 1, d) decode rows; xc: (Bc, Sc, d) prefill-chunk rows. The
    decode half writes/attends first, then the chunk half writes its span
    into the same cache (disjoint slots; on the dense layout the decode
    half's speculative write into a mid-prefill slot is overwritten by that
    slot's chunk, which starts exactly at its length). Full self-attention
    mixers only. Returns (xd, xc, new_cache, moe_counts)."""
    from repro.models.attention import (attention_chunk_step,
                                        attention_decode_step,
                                        paged_attention_chunk_step,
                                        paged_attention_decode_step)
    if kind.mixer != ATTN:
        raise ValueError(
            f"unified mixed stages support full self-attention decoder "
            f"layers only, got mixer={kind.mixer}")
    Bd = xd.shape[0]
    Bc, Sc, d = xc.shape
    h_d = rmsnorm(params["norm1"], xd, cfg.norm_eps)
    h_c = rmsnorm(params["norm1"], xc, cfg.norm_eps)
    if "k_pages" in cache:
        mixer_d, cache_d = paged_attention_decode_step(
            params["mixer"], cfg, h_d, cache, attn_ctx)
        mixer_c, new_cache = paged_attention_chunk_step(
            params["mixer"], cfg, h_c, cache_d, chunk_ctx)
    else:
        mixer_d, upd = attention_decode_step(params["mixer"], cfg, h_d,
                                             cache)
        cache_d = dict(cache)
        cache_d.update(upd)
        mixer_c, new_cache = attention_chunk_step(params["mixer"], cfg, h_c,
                                                  cache_d, chunk_ctx)
    counts = None
    if cfg.parallel_block and kind.ffn != NONE:
        ffn_in_d, ffn_in_c = h_d, h_c
        base_d, base_c = xd + mixer_d, xc + mixer_c
    else:
        xd = xd + mixer_d
        xc = xc + mixer_c
        if kind.ffn == NONE:
            return xd, xc, new_cache, counts
        ffn_in_d = rmsnorm(params["norm2"], xd, cfg.norm_eps)
        ffn_in_c = rmsnorm(params["norm2"], xc, cfg.norm_eps)
        base_d, base_c = xd, xc
    flat = jnp.concatenate([ffn_in_d.reshape(Bd, d),
                            ffn_in_c.reshape(Bc * Sc, d)], axis=0)
    if kind.ffn == MOE:
        dec_valid = attn_ctx.get("valid") if attn_ctx else None
        if dec_valid is None:
            dec_valid = jnp.ones((Bd,), bool)
        chunk_valid = (jnp.arange(Sc, dtype=jnp.int32)[None]
                       < chunk_ctx["chunk_lens"][:, None].astype(jnp.int32))
        valid = jnp.concatenate([dec_valid, chunk_valid.reshape(-1)])
        y, stats = moe_execute(params["ffn"], cfg, flat, return_stats=True,
                               token_valid=valid)
        if collect_counts:
            counts = stats.counts.astype(jnp.float32)
    else:
        y = ffn_apply(params["ffn"], flat)
    yd = y[:Bd].reshape(Bd, 1, d)
    yc = y[Bd:].reshape(Bc, Sc, d)
    return base_d + yd, base_c + yc, new_cache, counts


def segment_mixed_step(params, cfg: ModelConfig, seg: Segment, xd, xc,
                       cache, attn_ctx, chunk_ctx,
                       collect_counts: bool = False):
    """Scan the segment's stacked super-blocks over both row groups.
    Returns (xd, xc, new_cache, counts) — counts summed over layers."""
    E = cfg.moe.num_experts if (collect_counts and cfg.moe) else 0

    def body(carry, inp):
        xd, xc = carry
        blk_params, blk_cache = inp
        new_caches = []
        counts = jnp.zeros((E,), jnp.float32)
        for i, kind in enumerate(seg.pattern):
            xd, xc, nc, cnt = block_mixed_step(
                blk_params["blocks"][i], cfg, kind, xd, xc,
                blk_cache["blocks"][i], attn_ctx, chunk_ctx,
                collect_counts=collect_counts)
            new_caches.append(nc)
            if cnt is not None and E:
                counts = counts + cnt
        return (xd, xc), ({"blocks": tuple(new_caches)}, counts)

    (xd, xc), (new_cache, counts) = jax.lax.scan(body, (xd, xc),
                                                 (params, cache))
    return xd, xc, new_cache, counts.sum(axis=0)


def segment_prefill(params, cfg: ModelConfig, seg: Segment, x, positions,
                    true_len, cache, *, segment_ids=None, enc_out=None):
    def body(x, inp):
        blk_params, blk_cache = inp
        new_caches = []
        for i, kind in enumerate(seg.pattern):
            x, nc = block_prefill(blk_params["blocks"][i], cfg, kind, x,
                                  positions, true_len, blk_cache["blocks"][i],
                                  segment_ids=segment_ids, enc_out=enc_out)
            new_caches.append(nc)
        return x, {"blocks": tuple(new_caches)}

    x, new_cache = jax.lax.scan(body, x, (params, cache))
    return x, new_cache
