"""KV-cache preemption: migration and recomputation (paper §VIII-C).

When the KV capacity is exhausted and new requests are starving, the engine
evicts a running request and reclaims its slot. Two policies, per the
paper's discussion of PagedAttention:

  * ``migrate``   — the request's per-slot KV cache is copied to host
    memory; when a slot frees up, the cache is scattered back and decoding
    resumes where it left off (no recompute).
  * ``recompute`` — the cache is simply dropped; the request re-enters the
    queue with prompt = original prompt + generated-so-far and is
    re-prefilled later (trades compute for host memory/PCIe).

Paged layout (PR 5): eviction is page-granular — ``KVManager.free`` decrefs
the victim's block-table pages, so a shared prefix survives under its other
owners and only privately-owned pages return to the pool. Paged uses the
``recompute`` path (``migrate`` gathers dense slot rows and is dense-only);
with prefix sharing on, the replay re-matches whatever prefix pages are
still resident and skips re-prefilling them. This brings paged to parity
with dense preemption and lets a deployment oversubscribe ``num_pages``
against expected context lengths.

The migrate mechanics here are exactly the cache-slot gather/scatter the
paper's Duplex device would do against CPU memory.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import numpy as np

from repro.serving.kvmanager import KVManager
from repro.serving.request import Request, RequestState


def gather_slot(kv: KVManager, slot: int):
    """Pull one slot's cache (all layers) to host memory."""
    return [jax.tree_util.tree_map(lambda a: np.asarray(a[:, slot]), seg)
            for seg in kv.cache]


def restore_slot(kv: KVManager, slot: int, saved) -> None:
    """Scatter a host-saved cache back into a (new) slot."""
    def leaf(g, l):
        return g.at[:, slot].set(jax.numpy.asarray(l).astype(g.dtype))

    kv.cache = [jax.tree_util.tree_map(leaf, g, l)
                for g, l in zip(kv.cache, saved)]


def migrate_out(kv: KVManager, req: Request) -> None:
    """Evict `req`: save its cache to host, free the slot."""
    assert req.slot >= 0
    req.saved_cache = gather_slot(kv, req.slot)
    kv.free(req.slot)
    req.slot = -1
    req.state = RequestState.QUEUED


def recompute_out(kv: KVManager, req: Request) -> None:
    """Evict `req` dropping its cache; it will re-prefill prompt+output."""
    assert req.slot >= 0
    req.saved_cache = None
    kv.free(req.slot)
    req.slot = -1
    req.state = RequestState.QUEUED


def pick_victim(running: List[Request],
                now: Optional[float] = None) -> Optional[Request]:
    """Evict the lowest-``priority`` request, breaking ties by fewest
    generated tokens (least sunk work; vLLM evicts latest-arrived —
    equivalent under FCFS admission). With ``now`` given, a request already
    past its deadline is always the better victim regardless of priority —
    its work is dead either way (PR 6)."""
    decoding = [r for r in running if r.state == RequestState.DECODE
                and r.slot >= 0]
    if not decoding:
        return None
    if now is not None:
        return min(decoding, key=lambda r: (not r.past_deadline(now),
                                            r.priority, len(r.output)))
    return min(decoding, key=lambda r: (r.priority, len(r.output)))


def pick_victim_paged(candidates: List[Request],
                      now: Optional[float] = None) -> Optional[Request]:
    """Page-pressure victim, ordered by (priority, fewest generated tokens,
    latest arrival): the least-important least-sunk newest work goes first.
    Unlike ``pick_victim``, mid-prefill requests are eligible — they hold
    pages too and have the least sunk work of all. With ``now`` given,
    past-deadline requests are preferred over everything else (PR 6)."""
    pool = [r for r in candidates
            if r.slot >= 0 and r.state in (RequestState.DECODE,
                                           RequestState.PREFILL)]
    if not pool:
        return None
    if now is not None:
        return min(pool, key=lambda r: (not r.past_deadline(now),
                                        r.priority, len(r.output),
                                        -r.arrival_time, -r.rid))
    return min(pool, key=lambda r: (r.priority, len(r.output),
                                    -r.arrival_time, -r.rid))
