"""Expert co-processing partitioner — paper §V-B, reproduced unchanged.

Duplex must decide which experts to run on the xPU and which on Logic-PIM.
The paper's algorithm:

  1. Pre-compute lookup tables (LUTs) of per-expert execution time on each
     processor as a function of the number of tokens the expert serves.
  2. At runtime, start from "all experts on xPU", then *progressively assign
     the experts with the fewest tokens to Logic-PIM*, evaluating the makespan
     max(sum of xPU expert times, sum of Logic-PIM expert times) at each step,
     and keep the best split.

Because each path executes its experts sequentially (each expert GEMM uses the
whole unit), path time = sum over its experts; the two paths run concurrently,
so stage time = max of the two sums.

This module is shared verbatim by the serving runtime (`core/duplex_moe.py`
feeds it the router's token counts; the chosen *cold count* selects the static
GEMV-path width) and by the simulator (`sim/` uses it to model Duplex+PE).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.core.costmodel import DeviceSpec, DuplexSpec


# ---------------------------------------------------------------------------
# Latency lookup tables (paper: "preliminarily estimates and stores the
# processing times for experts in both xPU and Logic-PIM")
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ExpertLUT:
    """times[t] = seconds to run one expert FFN over t tokens on this device."""
    device: str
    times: np.ndarray          # (max_tokens + 1,)

    def __call__(self, tokens) -> np.ndarray:
        t = np.clip(np.asarray(tokens, dtype=np.int64), 0, len(self.times) - 1)
        return self.times[t]


def build_lut(dev: DeviceSpec, d_model: int, d_ff: int,
              max_tokens: int, mats: int = 3, *,
              block: Optional[int] = None,
              capacity: Optional[int] = None) -> ExpertLUT:
    """Expert FFN = ``mats`` GEMMs (3 for SwiGLU, 2 classic): flops =
    2·mats·t·d·f, bytes = weights (read once) + activations.

    Cost modes — the LUT must model what the kernel actually executes:

      * default (``block=capacity=None``): ideal live-token cost, weights
        streamed once — the gather-GEMV (cold/PIM) path;
      * ``capacity``: the capacity-padded grouped GEMM — any nonzero count
        executes the full padded slot buffer and re-streams the weights once
        per ``block``-sized token block (the pre-ragged hot path);
      * ``block`` alone: the ragged grouped GEMM — live tokens rounded up to
        token blocks, weights re-streamed once per *live* block.
    """
    t = np.arange(max_tokens + 1, dtype=np.float64)
    w_once = 2.0 * mats * d_model * d_ff
    if capacity is not None:
        nb = np.where(t > 0, float(-(-capacity // (block or capacity))), 0.0)
        t_eff = np.where(t > 0, float(capacity), 0.0)
    elif block is not None:
        nb = np.ceil(t / block)
        t_eff = nb * block
    else:
        nb = (t > 0).astype(np.float64)
        t_eff = t
    flops = 2.0 * mats * t_eff * d_model * d_ff
    w_bytes = w_once * nb
    a_bytes = 2.0 * t_eff * (2 * d_model + mats * d_ff)
    bytes_ = np.where(t > 0, w_bytes + a_bytes, 0.0)
    times = np.maximum(flops / dev.peak_flops, bytes_ / dev.mem_bw)
    times = np.where(t > 0, times + dev.t_launch, 0.0)
    return ExpertLUT(dev.name, times)


def build_luts(duplex: DuplexSpec, d_model: int, d_ff: int,
               max_tokens: int, mats: int = 3, *,
               hot_block: Optional[int] = None,
               hot_capacity: Optional[int] = None
               ) -> Tuple[ExpertLUT, ExpertLUT]:
    """(xPU LUT, PIM LUT). ``hot_block``/``hot_capacity`` select the xPU
    (grouped-GEMM) cost mode — ragged live-block vs capacity-padded — so the
    greedy k_cold split reflects what the hot kernel actually executes; the
    PIM (GEMV) path keeps the ideal live-token cost."""
    return (build_lut(duplex.xpu, d_model, d_ff, max_tokens, mats,
                      block=hot_block, capacity=hot_capacity),
            build_lut(duplex.pim, d_model, d_ff, max_tokens, mats))


# ---------------------------------------------------------------------------
# The greedy makespan partitioner (paper §V-B)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class Partition:
    """Result: experts in ``cold`` run on Logic-PIM, the rest on xPU."""
    cold: Tuple[int, ...]          # expert ids, ascending token count
    hot: Tuple[int, ...]
    t_xpu: float                   # sum of xPU expert times
    t_pim: float                   # sum of PIM expert times

    @property
    def makespan(self) -> float:
        return max(self.t_xpu, self.t_pim)

    @property
    def k_cold(self) -> int:
        return len(self.cold)


def partition_experts(counts: Sequence[int], lut_xpu: ExpertLUT,
                      lut_pim: ExpertLUT,
                      max_cold: Optional[int] = None) -> Partition:
    """Paper's algorithm: all-on-xPU start; move fewest-token experts to PIM
    one at a time; keep the best makespan seen.

    ``max_cold`` optionally caps the PIM set (runtime uses it to bound the
    static cold-path width).
    """
    counts = np.asarray(counts, dtype=np.int64)
    E = len(counts)
    order = np.argsort(counts, kind="stable")          # ascending token count
    tx = lut_xpu(counts)
    tp = lut_pim(counts)

    t_xpu = float(tx.sum())
    t_pim = 0.0
    best = Partition(cold=(), hot=tuple(int(e) for e in order),
                     t_xpu=t_xpu, t_pim=0.0)
    limit = E if max_cold is None else min(max_cold, E)
    for k in range(1, limit + 1):
        e = int(order[k - 1])
        t_xpu -= float(tx[e])
        t_pim += float(tp[e])
        if max(t_xpu, t_pim) < best.makespan:
            best = Partition(cold=tuple(int(x) for x in order[:k]),
                             hot=tuple(int(x) for x in order[k:]),
                             t_xpu=t_xpu, t_pim=t_pim)
    return best


def optimal_partition_bruteforce(counts: Sequence[int], lut_xpu: ExpertLUT,
                                 lut_pim: ExpertLUT) -> float:
    """Exhaustive best makespan over all 2^E subsets — test oracle only."""
    counts = np.asarray(counts, dtype=np.int64)
    E = len(counts)
    assert E <= 16, "bruteforce oracle only for small E"
    tx = lut_xpu(counts)
    tp = lut_pim(counts)
    best = float(tx.sum())
    for mask in range(1, 1 << E):
        t_pim = sum(float(tp[e]) for e in range(E) if mask >> e & 1)
        t_xpu = sum(float(tx[e]) for e in range(E) if not mask >> e & 1)
        best = min(best, max(t_xpu, t_pim))
    return best


# ---------------------------------------------------------------------------
# Runtime planner: static cold-count selection (DESIGN.md §8)
# ---------------------------------------------------------------------------

@dataclass
class DuplexPlanner:
    """Serving-side wrapper: jit needs a *static* cold-expert count, so the
    planner picks ``k_cold`` from the previous stage's router counts
    (one-stage-stale statistics — standard serving practice) and snaps it to a
    small set of bucket sizes to bound recompilation.
    """
    lut_xpu: ExpertLUT
    lut_pim: ExpertLUT
    num_experts: int
    buckets: Tuple[int, ...] = ()

    def __post_init__(self):
        if not self.buckets:
            E = self.num_experts
            raw = sorted({0, E // 8, E // 4, E // 2, 3 * E // 4, E})
            self.buckets = tuple(b for b in raw if 0 <= b <= E)
        self._last_k = 0

    def plan(self, counts: Sequence[int]) -> Partition:
        return partition_experts(counts, self.lut_xpu, self.lut_pim)

    def k_cold_static(self, counts: Optional[Sequence[int]]) -> int:
        """Bucketized k_cold for the next jitted stage step."""
        if counts is None:
            return self._last_k
        part = self.plan(counts)
        k = part.k_cold
        snapped = min(self.buckets, key=lambda b: (abs(b - k), b))
        self._last_k = snapped
        return snapped
