from repro.models.model import (abstract_cache, abstract_model, decode_step,
                                forward, init_cache, init_model, loss_fn,
                                model_specs, prefill)

__all__ = ["abstract_cache", "abstract_model", "decode_step", "forward",
           "init_cache", "init_model", "loss_fn", "model_specs", "prefill"]
