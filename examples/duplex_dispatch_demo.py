"""Demo: the paper's expert co-processing partitioner (§V-B) end to end.

Shows, for progressively skewed expert loads, how the greedy LUT
partitioner splits experts between xPU and Logic-PIM, and how close the
greedy makespan is to the exhaustive optimum (test oracle).

Run: PYTHONPATH=src python examples/duplex_dispatch_demo.py
"""
import numpy as np

from repro.core.costmodel import DUPLEX
from repro.core.partition import (build_luts, optimal_partition_bruteforce,
                                  partition_experts)

D_MODEL, D_FF, E = 4096, 14336, 8          # Mixtral-like layer
lut_x, lut_p = build_luts(DUPLEX, D_MODEL, D_FF, max_tokens=4096)

print(f"{'skew':>6s} {'counts':>40s} {'k_cold':>6s} {'makespan_us':>12s} "
      f"{'all_xpu_us':>11s} {'greedy/opt':>10s}")
rng = np.random.default_rng(0)
for skew in (0.0, 0.5, 1.0, 2.0, 4.0):
    # Zipf-ish skew over 8 experts, 64 assignments (batch 32, top-2)
    w = (1.0 / (np.arange(E) + 1) ** skew)
    counts = rng.multinomial(64, w / w.sum())
    part = partition_experts(counts, lut_x, lut_p)
    t_all_xpu = float(lut_x(counts).sum())
    opt = optimal_partition_bruteforce(counts, lut_x, lut_p)
    print(f"{skew:6.1f} {str(counts.tolist()):>40s} {part.k_cold:6d} "
          f"{part.makespan*1e6:12.1f} {t_all_xpu*1e6:11.1f} "
          f"{part.makespan/opt:10.3f}")

print("\nWith hot/cold experts (skew>0) the split wins; with uniform counts "
      "co-processing helps less (paper §VIII-B).")
print("OK")
