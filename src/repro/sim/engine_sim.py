"""Continuous-batching serving simulator (paper §VI).

Event loop at stage granularity: each iteration forms a stage (admitting
queued requests into free KV slots => mixed stage), asks ``layermodel`` for
the stage latency + energy under the chosen policy, advances virtual time,
and records per-request T2FT / TBT / E2E (paper Fig. 2). Throughput =
generated tokens / total time; energy is tallied per stage.

``split`` mode (Fig. 16 / Splitwise §VIII-A) partitions the devices into a
prefill pool and a decode pool; prompts run on the prefill pool (its own
queue), the KV migrates (NVLink transfer), decode stages run on the decode
pool — no mixed stages, but each pool holds a full weight copy and only
half the compute serves each phase.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.opb import StageMix
from repro.sim.cluster import SystemSpec, kv_bytes_per_token, max_batch_size
from repro.sim.layermodel import stage_exec
from repro.sim.workload import SimRequest


@dataclass
class SimResult:
    requests: List[SimRequest]
    total_time: float
    total_energy: float
    stages: int
    mixed_stages: int
    tokens_out: int

    @property
    def throughput(self) -> float:
        return self.tokens_out / max(self.total_time, 1e-12)

    @property
    def energy_per_token(self) -> float:
        return self.total_energy / max(self.tokens_out, 1)


def simulate(system: SystemSpec, cfg: ModelConfig, policy: str,
             requests: List[SimRequest], *, max_batch: Optional[int] = None,
             max_prefill_per_stage: int = 1, seed: int = 0,
             weight_copies: int = 1, max_stages: int = 2_000_000
             ) -> SimResult:
    rng = np.random.default_rng(seed)
    max_ctx = max(r.l_in + r.l_out for r in requests)
    cap = max_batch_size(system, cfg, max_ctx, weight_copies=weight_copies)
    batch_limit = min(max_batch or cap, cap) or 1

    queue = sorted(requests, key=lambda r: r.arrival)
    qi = 0
    running: List[SimRequest] = []
    progress: Dict[int, int] = {}         # rid -> tokens generated
    now = 0.0
    energy = 0.0
    stages = mixed = tokens_out = 0

    while (qi < len(queue) or running) and stages < max_stages:
        # admit arrived requests into free slots
        admitted: List[SimRequest] = []
        while (qi < len(queue) and queue[qi].arrival <= now
               and len(running) + len(admitted) < batch_limit
               and len(admitted) < max_prefill_per_stage):
            admitted.append(queue[qi])
            qi += 1
        if not running and not admitted:
            if qi < len(queue):
                now = queue[qi].arrival   # idle until next arrival
                continue
            break

        mix = StageMix(
            decode_ctx=tuple(r.l_in + progress[r.rid] for r in running),
            prefill_len=tuple(r.l_in for r in admitted))
        ex = stage_exec(system, cfg, mix, policy, rng=rng)
        now += ex.time
        energy += ex.energy
        stages += 1
        mixed += 1 if mix.is_mixed else 0

        # every participant emits one token
        for r in admitted:
            progress[r.rid] = 1
            r.first_token_time = now
            r.token_times.append(now)
            tokens_out += 1
            running.append(r)
        for r in list(running):
            if r in admitted:
                continue
            progress[r.rid] += 1
            r.token_times.append(now)
            tokens_out += 1
        for r in list(running):
            if progress[r.rid] >= r.l_out:
                r.finish_time = now
                running.remove(r)
    return SimResult(requests, now, energy, stages, mixed, tokens_out)


def simulate_split(system_prefill: SystemSpec, system_decode: SystemSpec,
                   cfg: ModelConfig, policy: str,
                   requests: List[SimRequest], *, seed: int = 0
                   ) -> SimResult:
    """Splitwise-style phase-split system (paper Fig. 16): prefill pool +
    decode pool, KV migration in between, each pool with its own weights."""
    rng = np.random.default_rng(seed)
    max_ctx = max(r.l_in + r.l_out for r in requests)
    cap_dec = max_batch_size(system_decode, cfg, max_ctx, weight_copies=1)
    kv_tok = kv_bytes_per_token(cfg)

    # prefill pool: sequential prompt processing (its own little queue)
    t_pre = 0.0
    energy = 0.0
    ready: List[SimRequest] = []
    for r in sorted(requests, key=lambda x: x.arrival):
        mix = StageMix(prefill_len=(r.l_in,))
        ex = stage_exec(system_prefill, cfg, mix, policy, rng=rng)
        t_pre = max(t_pre, r.arrival) + ex.time
        energy += ex.energy
        # KV migration to the decode pool
        t_pre += kv_tok * r.l_in / system_prefill.nvlink_bw
        r.first_token_time = t_pre
        r.token_times.append(t_pre)
        ready.append(r)

    # decode pool: continuous batching over decode-only stages
    now = 0.0
    running: List[SimRequest] = []
    progress: Dict[int, int] = {}
    tokens_out = len(ready)
    stages = 0
    idx = 0
    ready_sorted = sorted(ready, key=lambda r: r.first_token_time)
    while idx < len(ready_sorted) or running:
        while (idx < len(ready_sorted)
               and ready_sorted[idx].first_token_time <= now
               and len(running) < max(cap_dec, 1)):
            r = ready_sorted[idx]
            progress[r.rid] = 1
            running.append(r)
            idx += 1
        if not running:
            now = ready_sorted[idx].first_token_time
            continue
        mix = StageMix(decode_ctx=tuple(r.l_in + progress[r.rid]
                                        for r in running))
        ex = stage_exec(system_decode, cfg, mix, policy, rng=rng)
        now += ex.time
        energy += ex.energy
        stages += 1
        for r in list(running):
            progress[r.rid] += 1
            r.token_times.append(max(now, r.first_token_time))
            tokens_out += 1
            if progress[r.rid] >= r.l_out:
                r.finish_time = max(now, r.first_token_time)
                running.remove(r)
    total = max(now, t_pre)
    return SimResult(requests, total, energy, stages, 0, tokens_out)
