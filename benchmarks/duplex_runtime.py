"""TPU-runtime counterpart of the paper's C2 claim: the duplex (hot/cold)
MoE path removes capacity-padding waste vs the single-capacity grouped path.

Lowers one decode step of a 64-expert MoE (GLaM-like routing at decode batch
sizes) both ways and compares trip-count-aware HLO FLOPs/bytes (the same
accounting as §Roofline). No hardware needed: the win is structural.
"""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig, small_test_config
from repro.core.execution import ExecutionPlan, execution_plan
from repro.launch.hlo_cost import analyze


def _lower_flops(cfg, params, tokens, cache, plan) -> Dict[str, float]:
    from repro.models.model import decode_step

    @jax.jit
    def step(params, tokens, cache):
        with execution_plan(plan):
            logits, new_cache = decode_step(params, cfg, tokens, cache)
        return logits

    compiled = step.lower(params, tokens, cache).compile()
    cost, _ = analyze(compiled.as_text())
    return {"flops": cost.flops, "bytes": cost.bytes}


def run(quick: bool = True) -> List[Dict]:
    import numpy as np

    from repro.models.model import init_cache, init_model

    rows = []
    E, top_k = 64, 2
    cfg = small_test_config(
        "glam-like", family="moe", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, d_ff=512, vocab_size=512,
        moe=MoEConfig(num_experts=E, top_k=top_k, d_ff_expert=512))
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    for batch in (32, 128) if quick else (32, 64, 128, 256):
        cache = init_cache(cfg, batch, 256)
        tokens = jnp.zeros((batch, 1), jnp.int32)
        # drop-free apples-to-apples: both paths sized to the same observed
        # max expert load; duplex additionally caps cold experts at the tail
        counts = rng.multinomial(batch * top_k, np.full(E, 1.0 / E))
        c_hot = int(counts.max()) + 1
        k_cold = int((counts <= np.median(counts)).sum())
        c_cold = int(np.sort(counts)[k_cold - 1]) + 1
        grouped = _lower_flops(cfg, params, tokens, cache,
                               ExecutionPlan(moe_impl="grouped",
                                             moe_capacity=c_hot))
        duplex = _lower_flops(cfg, params, tokens, cache,
                              ExecutionPlan(moe_impl="duplex", k_cold=k_cold,
                                            c_hot=c_hot, c_cold=c_cold))
        rows.append({
            "batch": batch, "experts": E, "k_cold": k_cold,
            "c_hot": c_hot, "c_cold": c_cold,
            "grouped_mflops": grouped["flops"] / 1e6,
            "duplex_mflops": duplex["flops"] / 1e6,
            "flop_reduction": 1 - duplex["flops"] / grouped["flops"],
            "byte_reduction": 1 - duplex["bytes"] / grouped["bytes"],
        })
    return rows


if __name__ == "__main__":
    from benchmarks.common import print_rows
    print_rows("duplex_runtime", run(quick=False))
