"""Sharding-rule resolution, divisibility fitting, and hlo_cost walker."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_mesh
from repro.sharding.rules import (base_rules, fit_pspec_to_shape,
                                  resolve_pspec, rules_for)


def test_resolve_first_wins_dedup():
    rules = {"a": "model", "b": "model", "c": ("data", "model")}
    spec = resolve_pspec(("a", "b", "c"), rules)
    # 'b' and the model element of 'c' are dropped (already used)
    assert spec == P("model", None, "data")


def test_resolve_none_axes():
    rules = {"a": "data"}
    assert resolve_pspec((None, "a", "missing"), rules) == P(None, "data",
                                                             None)


def test_fit_drops_nondividing():
    mesh = make_mesh((1,), ("model",))
    # fake a 16-way axis via a mesh-shaped namespace
    class FakeMesh:
        shape = {"model": 16, "data": 4}
    spec = fit_pspec_to_shape(P("model", "data"), (12, 8), FakeMesh)
    assert spec == P(None, "data")       # 12 % 16 != 0 dropped; 8 % 4 == 0


def test_fit_keeps_dividing_prefix():
    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    spec = fit_pspec_to_shape(P(("pod", "data", "model"),), (64,), FakeMesh)
    # 64 % 2 == 0, 64 % 32 == 0, 64 % 512 != 0 -> keep (pod, data)
    assert spec == P(("pod", "data"))


def test_base_rules_moe_modes():
    tp = base_rules(multi_pod=False, shape_kind="train", moe_sharding="tp")
    assert tp["experts"] is None and tp["expert_mlp"] == "model"
    ep = base_rules(multi_pod=False, shape_kind="train", moe_sharding="ep")
    assert ep["experts"] == "model"
    auto = base_rules(multi_pod=True, shape_kind="train", moe_sharding="auto")
    assert auto["experts"] == "pod" and auto["act_batch"] == "data"


def test_logical_constraint_identity_outside_context():
    from repro.sharding.rules import logical_constraint
    x = jnp.ones((4, 4))
    y = logical_constraint(x, ("act_batch", "act_seq"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# HLO cost walker
# ---------------------------------------------------------------------------

def test_hlo_walker_counts_scan_trips():
    from repro.launch.hlo_cost import analyze

    def scanned(x, w):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        return jax.lax.scan(body, x, w)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    txt = jax.jit(scanned).lower(x, w).compile().as_text()
    cost, sites = analyze(txt)
    expected = 5 * 2 * 64 ** 3
    assert cost.flops == pytest.approx(expected, rel=0.05)
    assert any(s.mult == 5 for s in sites)


def test_hlo_walker_dus_bytes_are_slice_sized():
    from repro.launch.hlo_cost import analyze

    def f(cache, x):
        return jax.lax.dynamic_update_slice(cache, x, (0, 0))

    cache = jax.ShapeDtypeStruct((4096, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((1, 128), jnp.float32)
    txt = jax.jit(f, donate_argnums=0).lower(cache, x).compile().as_text()
    cost, _ = analyze(txt)
    # traffic ~ the 1x128 update, NOT the 4096x128 buffer
    assert cost.bytes < 4096 * 128 * 4 * 0.5


def test_hlo_walker_collectives():
    from repro.launch.roofline import collective_bytes_from_hlo
    hlo = ('%ag = f32[64]{0} all-gather(f32[16]{0} %x), dimensions={0}\n'
           '%ar.1 = bf16[8,8]{1,0} all-reduce(bf16[8,8]{1,0} %y), to_apply=%s\n')
    out = collective_bytes_from_hlo(hlo)
    assert out["all-gather"] == 64
    assert out["all-reduce"] == 128


def test_dispatch_grid_resolution():
    from repro.launch.steps import dispatch_grid

    class FakeMesh:
        shape = {"pod": 2, "data": 16, "model": 16}
    rules = {"act_batch": ("pod", "data"), "act_seq": "model"}
    assert dispatch_grid(FakeMesh, rules) == (32, 16)
    rules2 = {"act_batch": None, "act_seq": None}
    assert dispatch_grid(FakeMesh, rules2) == (1, 1)
