"""Pallas TPU kernels for the paper's two execution paths + jnp oracles.

compute path (xPU analogue):    flash_attn.py, moe_gemm.py
bandwidth path (Logic-PIM):     decode_attn.py (dense + paged), moe_gemv.py
wrappers / oracles:             ops.py, ref.py
"""
from jax.experimental.pallas import tpu as _pltpu

# --- JAX version compat -----------------------------------------------------
# The TPU compiler-params dataclass was renamed across JAX releases
# (TPUCompilerParams <-> CompilerParams). Every kernel module builds its
# compiler params through this shim so either spelling of JAX works.
_COMPILER_PARAMS_CLS = getattr(_pltpu, "CompilerParams", None) or getattr(
    _pltpu, "TPUCompilerParams")


def tpu_compiler_params(**kwargs):
    """Construct pltpu compiler params under whichever name this JAX has."""
    return _COMPILER_PARAMS_CLS(**kwargs)


import jax.numpy as jnp


def int8_quantize(x, *, keepdims: bool = False):
    """Canonical int8 abs-max quantization over the last axis: THE one
    recipe (abs-max / 127 steps, 1e-8 scale floor) shared by the
    model-layer KV cache (models/attention.py::quantize_kv) and the
    in-kernel q/pv requantization of the int8 paged kernels
    (decode_attn.py). Dense<->paged greedy-token parity depends on both
    paths quantizing bit-identically, so there is exactly one definition.
    Returns (int8 values, fp32 scale [``keepdims`` keeps the reduced
    axis])."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                   keepdims=keepdims)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    div = scale if keepdims else scale[..., None]
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / div),
                 -127, 127).astype(jnp.int8)
    return q, scale


from repro.kernels.ops import (decode_attention, flash_attention, moe_gemm,
                               moe_gemv, paged_decode_attention,
                               ragged_moe_gemm)

__all__ = ["decode_attention", "flash_attention", "int8_quantize",
           "moe_gemm", "moe_gemv", "paged_decode_attention",
           "ragged_moe_gemm", "tpu_compiler_params"]
