"""Dense gated FFN (SwiGLU family)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.param import ParamSpec
from repro.sharding.rules import logical_constraint


def ffn_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    pdtype = cfg.param_dtype
    if not cfg.gated_ffn:        # classic 2-matrix FFN (GLaM/OPT style)
        return {
            "wi": ParamSpec((d, f), pdtype, ("embed", "mlp")),
            "wo": ParamSpec((f, d), pdtype, ("mlp", "embed")),
        }
    return {
        "wi_gate": ParamSpec((d, f), pdtype, ("embed", "mlp")),
        "wi_up": ParamSpec((d, f), pdtype, ("embed", "mlp")),
        "wo": ParamSpec((f, d), pdtype, ("mlp", "embed")),
    }


def ffn_apply(params, x):
    if "wi" in params:           # non-gated
        h = jnp.einsum("...d,df->...f", x, params["wi"])
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
        h = logical_constraint(h, ("act_batch", "act_seq", "act_mlp"))
        y = jnp.einsum("...f,fd->...d", h, params["wo"])
    else:
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"])
        u = jnp.einsum("...d,df->...f", x, params["wi_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
        h = logical_constraint(h, ("act_batch", "act_seq", "act_mlp"))
        y = jnp.einsum("...f,fd->...d", h, params["wo"])
    # constrain the down-proj output: its TP partial-sum all-reduce must
    # land batch-sharded, not replicated (shows up as a full-token-buffer
    # all-reduce per layer otherwise)
    if y.ndim == 3:
        y = logical_constraint(y, ("act_batch", "act_seq", "act_embed"))
    return y
