"""Fault-tolerant training loop.

Scale features (DESIGN.md §5):
  * checkpoint/restart: atomic keep-k checkpoints, elastic re-sharding
    restore (device count may change between runs);
  * SIGTERM/SIGINT-safe: a signal requests a final checkpoint at the next
    step boundary before exiting (preemption handling);
  * deterministic resumable data (index-based — restores mid-epoch exactly);
  * straggler watchdog: per-step wall-time EWMA; steps slower than
    ``straggler_factor``x the EWMA are logged and counted (on real fleets
    this feeds the health controller that evicts slow hosts);
  * microbatched gradient accumulation with fp32 accumulators and optional
    int8-EF cross-pod compression (launch/steps.py builds the step fn).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.training.checkpoint import (latest_checkpoint, restore_checkpoint,
                                       save_checkpoint)


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    async_ckpt: bool = False      # background-thread saves (overlap with step)
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2


@dataclass
class LoopState:
    step: int = 0
    ewma_step_s: float = 0.0
    stragglers: int = 0
    losses: List[float] = field(default_factory=list)
    interrupted: bool = False


def train_loop(state, step_fn: Callable, batch_fn: Callable[[int], Any],
               cfg: LoopConfig, *, state_template=None, shardings=None,
               log: Callable[[str], None] = print) -> LoopState:
    """Run ``step_fn(state, batch) -> (state, metrics)`` for cfg.total_steps.

    Restores from the latest checkpoint in ckpt_dir if one exists (elastic:
    ``shardings`` may target a different mesh than the saving run used).
    """
    loop = LoopState()
    start = 0
    if cfg.ckpt_dir and latest_checkpoint(cfg.ckpt_dir) is not None:
        state, start = restore_checkpoint(
            cfg.ckpt_dir, state_template or jax.eval_shape(lambda: state),
            shardings=shardings)
        loop.step = start
        log(f"[loop] restored step {start} from {cfg.ckpt_dir}")

    async_ck = None
    if cfg.ckpt_dir and cfg.async_ckpt:
        from repro.training.checkpoint import AsyncCheckpointer
        async_ck = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)

    stop_requested = {"flag": False}
    prev_handlers = {}

    def _handler(signum, frame):
        stop_requested["flag"] = True
        log(f"[loop] signal {signum}: checkpoint-and-exit at next boundary")

    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            prev_handlers[sig] = signal.signal(sig, _handler)
        except ValueError:   # non-main thread (tests)
            pass

    try:
        for step in range(start, cfg.total_steps):
            t0 = time.monotonic()
            batch = batch_fn(step)
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            loop.step = step + 1
            loop.losses.append(loss)
            if loop.ewma_step_s == 0.0:
                loop.ewma_step_s = dt
            else:
                if dt > cfg.straggler_factor * loop.ewma_step_s:
                    loop.stragglers += 1
                    log(f"[loop] straggler step {step}: {dt:.3f}s vs "
                        f"EWMA {loop.ewma_step_s:.3f}s")
                loop.ewma_step_s = ((1 - cfg.ewma_alpha) * loop.ewma_step_s
                                    + cfg.ewma_alpha * dt)
            if step % cfg.log_every == 0:
                log(f"[loop] step {step} loss {loss:.4f} ({dt:.3f}s)")
            boundary = (cfg.ckpt_dir and
                        ((step + 1) % cfg.ckpt_every == 0
                         or step + 1 == cfg.total_steps
                         or stop_requested["flag"]))
            if boundary:
                if async_ck is not None:
                    async_ck.save(step + 1, state)
                else:
                    save_checkpoint(cfg.ckpt_dir, step + 1, state,
                                    keep=cfg.keep)
            if stop_requested["flag"]:
                loop.interrupted = True
                break
    finally:
        if async_ck is not None:
            async_ck.wait()
        for sig, h in prev_handlers.items():
            signal.signal(sig, h)
    return loop
