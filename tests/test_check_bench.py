"""The benchmark trend gate (tools/check_bench.py, PR 7 satellite)."""
import importlib.util
import json
import os

_spec = importlib.util.spec_from_file_location(
    "check_bench", os.path.join(os.path.dirname(__file__), os.pardir,
                                "tools", "check_bench.py"))
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(_spec and check_bench)


def test_compare_rows_band_and_identity():
    base = [{"case": "a", "goodput": 0.9, "completed": 40,
             "drain_clean": True, "t_kernel": 0.5, "tokens_s_paged": 12.0}]
    # inside the band + wall-time fields wildly off -> no problems
    cur = [{"case": "a", "goodput": 0.85, "completed": 39,
            "drain_clean": True, "t_kernel": 9.9, "tokens_s_paged": 0.1}]
    assert check_bench.compare_rows("x", base, cur, rel=0.2, abs_tol=2) == []
    # identity flip + numeric drift outside the band -> both reported
    bad = [{"case": "b", "goodput": 0.3, "completed": 39,
            "drain_clean": False, "t_kernel": 0.5, "tokens_s_paged": 12.0}]
    probs = check_bench.compare_rows("x", base, bad, rel=0.2, abs_tol=0)
    assert any(".case:" in p for p in probs)
    assert any(".goodput:" in p for p in probs)
    assert any(".drain_clean:" in p for p in probs)
    assert not any("t_kernel" in p or "tokens_s" in p for p in probs)
    # row-count mismatch is a single structural problem
    assert check_bench.compare_rows("x", base, [], rel=0.2, abs_tol=2) == \
        ["x: row count 0 != baseline 1"]


def test_main_update_then_clean_pass(tmp_path):
    cur = tmp_path / "cur"
    baselines = tmp_path / "baselines"
    cur.mkdir()
    payload = {"benchmark": "demo", "rows": [{"case": "a", "goodput": 0.9}]}
    (cur / "demo.json").write_text(json.dumps(payload))
    # seed the baselines, then compare: clean
    assert check_bench.main(["--dir", str(cur), "--baselines",
                             str(baselines), "--update"]) == 0
    assert (baselines / "BENCH_demo.json").exists()
    assert check_bench.main(["--dir", str(cur), "--baselines",
                             str(baselines)]) == 0
    # drift outside the band: the gate fails
    payload["rows"][0]["goodput"] = 0.1
    (cur / "demo.json").write_text(json.dumps(payload))
    assert check_bench.main(["--dir", str(cur), "--baselines",
                             str(baselines), "--abs", "0"]) == 1
    # a baseline whose benchmark was not generated this run also fails
    (cur / "demo.json").unlink()
    assert check_bench.main(["--dir", str(cur), "--baselines",
                             str(baselines)]) == 1
